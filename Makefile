# Tier-1 verification: dependency hygiene + the full test suite, plus both
# alternate dispatch configurations.
#
#   make verify      - what CI runs; catches the dacite-class regression
#                      (a third-party import sneaking into the core path),
#                      then re-exercises the Pallas interpret dispatch layer,
#                      the 4-host-device data-parallel configuration, and the
#                      serving engine (incl. 4-fake-device sharded serving)
#   make smoke       - 2-step end-to-end training run through the Experiment
#                      front door (launch CLI + config-file path)
#   make smoke-dist  - same, sharded over 4 faked CPU devices with
#                      gradient-accumulation microbatching
#   make smoke-dist-2d - same on the 2-D dp=2×mp=2 mesh (FSDP/expert/head
#                      sharding per the PartitionPlan)
#   make test-serve  - serving engine suite on 4 faked devices + the
#                      sharded serve CLI end-to-end
#   make fuzz-serve  - 200 seeded submit/poll/fetch/drain interleavings
#                      against one warmed multi-tenant engine (deterministic:
#                      injected clock, seeded RNG, zero invariant violations)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
DIST_FLAGS := --xla_force_host_platform_device_count=4

.PHONY: verify deps-check lint test test-interpret test-dist test-serve \
	test-perf-dist test-pipeline fuzz-serve smoke smoke-dist smoke-dist-2d \
	bench-train

verify: deps-check lint test test-interpret test-dist test-serve \
	test-perf-dist test-pipeline fuzz-serve

# Core modules must import on a bare jax+numpy interpreter: no dacite, and
# zstandard/msgpack/hypothesis only ever loaded behind soft gates; the
# analysis package must import on NO third-party modules at all.
deps-check:
	$(PY) scripts/check_deps.py

# jaxlint: stdlib-ast static analysis for this repo's JAX bug classes
# (R001-R007; see `python -m repro.analysis --catalog`).  Fails on any
# finding that is neither inline-suppressed nor in .jaxlint-baseline.json.
lint:
	$(PY) -m repro.analysis src/repro benchmarks examples

test:
	$(PY) -m pytest -x -q

# Pallas dispatch layer: per-kernel oracles plus full trainer steps with
# REPRO_PALLAS=interpret (reward_improves is excluded — 45 interpret-mode
# steps add ~10 min for a signal the kernel mode doesn't change).
test-interpret:
	REPRO_PALLAS=interpret $(PY) -m pytest -x -q tests/test_kernels.py \
	    tests/test_trainers.py -k "not reward_improves"

# Distributed configuration: the in-process distributed tests re-run ON
# 4 faked host devices (the subprocess equivalence tests are deselected —
# they spawn their own 4-device children and already ran in `make test`),
# then the sharded + microbatched launch CLI end-to-end, in both mesh
# layouts: 1-D dp=4 and 2-D dp=2×mp=2.
test-dist:
	XLA_FLAGS="$(DIST_FLAGS)" $(PY) -m pytest -x -q \
	    tests/test_distributed.py \
	    -k "not sharded_training and not shard_map and not two_axis and not portable"
	$(MAKE) smoke-dist
	$(MAKE) smoke-dist-2d

# Serving engine: the suite re-run ON 4 faked host devices (the sharded
# subprocess test is deselected — it spawns its own 4-device child and
# already ran in `make test`), then the bucketed + sharded serve CLI
# end-to-end (dist.data_parallel=4, per-request bit-identical to dp=1).
test-serve:
	XLA_FLAGS="$(DIST_FLAGS)" $(PY) -m pytest -x -q tests/test_serving.py \
	    -k "not subprocess"
	XLA_FLAGS="$(DIST_FLAGS)" $(PY) -m repro.launch.serve --reduced \
	    --requests 9 --max-batch 4 --deadline-ms 2 \
	    --step-tiers 2 --stats-json /tmp/repro-serve-stats.json \
	    --set flow.num_steps=2 --set dist.data_parallel=4 \
	    --set 'data.encoder={"cond_dim": 512, "cond_len": 8, "vocab": 512, "hidden": 64}'
	$(PY) -c "import json; s = json.load(open('/tmp/repro-serve-stats.json')); \
	    assert s['cold_dispatches'] == 0 and s['step_tiers'] == [2], s"

# The serving fuzz corpus at full depth: 200 seeded interleavings (the
# tier-1 run uses the default 25).  Deterministic — same seeds, same
# injected clock, same op sequences — so a failure here is reproducible
# with REPRO_FUZZ_SEEDS=200 pytest tests/test_serving.py -k fuzz.
fuzz-serve:
	REPRO_FUZZ_SEEDS=200 $(PY) -m pytest -x -q tests/test_serving.py \
	    -k "fuzz"

# repro.perf composition: the perf tests whose remat/fusion × data-parallel
# × microbatch assertions need real (faked) devices re-run ON 4 of them
# (the single-device semantics already ran in `make test`)
test-perf-dist:
	XLA_FLAGS="$(DIST_FLAGS)" $(PY) -m pytest -x -q tests/test_perf.py \
	    -k "data_parallel or under_mesh"

# Pipelined train loop: the pipeline=K-vs-sequential equivalence suite
# re-run ON 4 faked host devices so the fused × data_parallel=4 × K
# composition test (skipped in `make test`) executes too.
test-pipeline:
	XLA_FLAGS="$(DIST_FLAGS)" $(PY) -m pytest -x -q tests/test_pipeline.py

# train-step perf trajectory: writes BENCH_train_step.json at the repo root
bench-train:
	$(PY) -m benchmarks.train_step

smoke:
	$(PY) -m repro.launch.train --reduced --steps 2 \
	    --set flow.num_steps=2 --set flow.group_size=2 \
	    --set flow.cache_dir=/tmp/repro-smoke/cache \
	    --set loop.ckpt_dir=/tmp/repro-smoke/ckpt

smoke-dist:
	rm -rf /tmp/repro-smoke-dist
	XLA_FLAGS="$(DIST_FLAGS)" $(PY) -m repro.launch.train --reduced \
	    --steps 2 --set dist.data_parallel=4 --set dist.microbatch=2 \
	    --set flow.cache_dir=/tmp/repro-smoke-dist/cache \
	    --set loop.ckpt_dir=/tmp/repro-smoke-dist/ckpt

# 2-D mesh smoke: dp=2 × mp=2 on 4 faked devices, params/moments sharded
# over the model axis per the PartitionPlan (perf.log_memory surfaces the
# per-device state bytes)
smoke-dist-2d:
	rm -rf /tmp/repro-smoke-dist-2d
	XLA_FLAGS="$(DIST_FLAGS)" $(PY) -m repro.launch.train --reduced \
	    --steps 2 --set dist.data_parallel=2 --set dist.model_parallel=2 \
	    --set perf.log_memory=true \
	    --set flow.cache_dir=/tmp/repro-smoke-dist-2d/cache \
	    --set loop.ckpt_dir=/tmp/repro-smoke-dist-2d/ckpt
