# Tier-1 verification: dependency hygiene + the full test suite.
#
#   make verify      - what CI runs; catches the dacite-class regression
#                      (a third-party import sneaking into the core path)
#   make smoke       - 2-step end-to-end training run through the Experiment
#                      front door (launch CLI + config-file path)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: verify deps-check test smoke

verify: deps-check test

# Core modules must import on a bare jax+numpy interpreter: no dacite, and
# zstandard/msgpack/hypothesis only ever loaded behind soft gates.
deps-check:
	$(PY) scripts/check_deps.py

test:
	$(PY) -m pytest -x -q

smoke:
	$(PY) -m repro.launch.train --reduced --steps 2 \
	    --set flow.num_steps=2 --set flow.group_size=2 \
	    --set flow.cache_dir=/tmp/repro-smoke/cache \
	    --set loop.ckpt_dir=/tmp/repro-smoke/ckpt
