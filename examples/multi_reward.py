"""Multi-reward training example (paper §2.3): pointwise + groupwise rewards
sharing one frozen backbone (deduplicated by ``model_id``), compared under
weighted_sum vs GDPO advantage aggregation — the aggregator is a single
dotted override on the same Experiment config.

  PYTHONPATH=src python examples/multi_reward.py
"""
import numpy as np

from repro.api import Experiment, apply_overrides
from repro.config import (DataConfig, FlowRLConfig, LoopConfig, OptimConfig,
                          RewardSpec, RunConfig)

BASE = RunConfig(
    arch="flux_dit", reduced=True,
    flow=FlowRLConfig(
        num_steps=4, group_size=4, latent_tokens=8, latent_dim=8,
        rewards=(
            # pointwise preference scorer
            RewardSpec("pickscore", 0.5, model_id="pickscore-base"),
            # groupwise pairwise-preference reward SHARING the same backbone
            RewardSpec("pref_group", 0.5, model_id="pickscore-base"),
            # task reward + regularizer (latent geometry auto-completed)
            RewardSpec("text_render", 1.0),
            RewardSpec("latent_norm", 0.1)),
        preprocessing=True, cache_dir="cache/multi_reward"),
    optim=OptimConfig(lr=3e-4, total_steps=15, warmup_steps=2),
    data=DataConfig(n_prompts=16, batch_prompts=2,
                    encoder=dict(cond_dim=64, cond_len=4, vocab=512,
                                 hidden=128)),
    loop=LoopConfig(steps=15, log_every=0, save_every=0, resume=False))

for agg in ("weighted_sum", "gdpo"):
    exp = Experiment.from_config(
        apply_overrides(BASE, [f"flow.advantage_agg={agg}"]))
    trainer = exp.build_trainer()
    print(f"\n[{agg}] {len(exp.flow.rewards)} reward configs -> "
          f"{trainer.loader.unique_loads} unique frozen models loaded "
          "(deduplication)")
    hist = exp.train()["history"]
    for row in hist[::5] + [hist[-1]]:
        per = {k.split('/')[-1]: round(v, 3) for k, v in row.items()
               if k.startswith("reward/")}
        print(f"  step {row['step']:3d} total={row['reward']:+.4f} "
              f"per-reward={per}")
    rewards = [r["reward"] for r in hist]
    print(f"  gain: {np.mean(rewards[-3:]) - np.mean(rewards[:3]):+.4f}")
