"""Multi-reward training example (paper §2.3): pointwise + groupwise rewards
sharing one frozen backbone (deduplicated), compared under weighted_sum vs
GDPO advantage aggregation.

  PYTHONPATH=src python examples/multi_reward.py
"""
import jax
import numpy as np

from repro import configs, registry
from repro.config import FlowRLConfig, OptimConfig, RewardSpec

key = jax.random.PRNGKey(0)
arch = configs.get_reduced("flux_dit")
opt = OptimConfig(lr=3e-4, total_steps=15, warmup_steps=2)
cond = jax.random.normal(key, (2, 4, 512))

REWARDS = (
    # pointwise preference scorer
    RewardSpec("pickscore", 0.5, model_id="pickscore-base",
               args={"latent_dim": 8}),
    # groupwise pairwise-preference reward SHARING the same frozen backbone
    RewardSpec("pref_group", 0.5, model_id="pickscore-base",
               args={"latent_dim": 8}),
    # task reward + regularizer
    RewardSpec("text_render", 1.0, args={"latent_dim": 8,
                                         "latent_tokens": 8}),
    RewardSpec("latent_norm", 0.1),
)

for agg in ("weighted_sum", "gdpo"):
    flow = FlowRLConfig(num_steps=4, group_size=4, latent_tokens=8,
                        latent_dim=8, advantage_agg=agg, rewards=REWARDS)
    tr = registry.build("trainer", "flow_grpo", arch, flow, opt, key=key)
    print(f"\n[{agg}] 4 reward configs -> {tr.loader.unique_loads} unique "
          "frozen models loaded (deduplication)")
    hist = []
    for it in range(15):
        m = tr.step(cond, key, it=it)
        hist.append(float(m["reward_mean"]))
        if it % 5 == 0:
            per = {k.split('/')[-1]: round(float(v), 3)
                   for k, v in m.items() if k.startswith("reward/")}
            print(f"  step {it:3d} total={hist[-1]:+.4f} per-reward={per}")
    print(f"  gain: {np.mean(hist[-3:]) - np.mean(hist[:3]):+.4f}")
