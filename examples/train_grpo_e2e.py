"""End-to-end driver: preprocess a prompt corpus, then RL fine-tune a
~100M-param FLUX-style DiT with Flow-GRPO, with full-state checkpointing
and a reward log — all from one declarative RunConfig (the custom model
size is plain ``arch_overrides`` data, not code).

Full run (~100M params, 200 steps):
  PYTHONPATH=src python examples/train_grpo_e2e.py
CI-scale sanity run:
  PYTHONPATH=src python examples/train_grpo_e2e.py --small --steps 10
"""
import argparse

import numpy as np

from repro.api import Experiment
from repro.config import (DataConfig, FlowRLConfig, LoopConfig, OptimConfig,
                          RewardSpec, RunConfig)

# ~100M-param member of the paper's DiT family, declared as data
MODEL_100M = {"n_layers": 12, "d_model": 768, "n_heads": 12,
              "n_kv_heads": 12, "d_ff": 3072, "head_dim": 64,
              "vocab_size": 4096}


def build_config(args) -> RunConfig:
    lat_tok, lat_dim = (8, 8) if args.small else (64, 16)
    return RunConfig(
        arch="flux_dit", reduced=args.small,
        arch_overrides={} if args.small else MODEL_100M,
        flow=FlowRLConfig(
            trainer_type="flow_grpo", sde_type="flow_sde", eta=0.7,
            num_steps=4 if args.small else 8,
            group_size=4, latent_tokens=lat_tok, latent_dim=lat_dim,
            advantage_agg="gdpo",
            rewards=(RewardSpec("text_render", 1.0),
                     RewardSpec("pickscore", 0.25),
                     RewardSpec("latent_norm", 0.1)),
            cache_dir=f"{args.out}/cache"),
        optim=OptimConfig(lr=3e-4, total_steps=args.steps,
                          warmup_steps=max(2, args.steps // 20)),
        data=DataConfig(n_prompts=64, batch_prompts=4),
        loop=LoopConfig(steps=args.steps, log_every=10, save_every=100,
                        ckpt_dir=f"{args.out}/ckpt",
                        log_file=f"{args.out}/reward_log.json"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--out", default="experiments/e2e")
    args = ap.parse_args()

    exp = Experiment.from_config(build_config(args))
    d = exp.describe()
    print(f"[e2e] {d['trainer']['name']} on {d['arch']['name']} "
          f"({d['arch']['n_params']/1e6:.1f}M params), "
          f"rewards={d['rewards']}")
    hist = exp.train()["history"]
    if not hist:
        print("[done] nothing left to train (resumed at final step)")
        return
    early = np.mean([r["reward"] for r in hist[:5]])
    late = np.mean([r["reward"] for r in hist[-5:]])
    print(f"[done] reward {early:+.4f} -> {late:+.4f} "
          f"({'improved' if late > early else 'no gain'})")


if __name__ == "__main__":
    main()
