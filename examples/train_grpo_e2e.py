"""End-to-end driver (deliverable b): preprocess a prompt corpus, then RL
fine-tune a ~100M-param FLUX-style DiT with Flow-GRPO for a few hundred
steps, with checkpointing and a reward log.

Full run (~100M params, 200 steps):
  PYTHONPATH=src python examples/train_grpo_e2e.py
CI-scale sanity run:
  PYTHONPATH=src python examples/train_grpo_e2e.py --small --steps 10
"""
import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro import checkpoint, configs, registry
from repro.config import ArchConfig, FlowRLConfig, OptimConfig, RewardSpec
from repro.core.preprocess import (ConditionProvider, PreprocessCache,
                                   preprocess_dataset)
from repro.data import PromptDataset, synthetic_prompts


def model_100m() -> ArchConfig:
    """~100M-param member of the paper's DiT family."""
    return dataclasses.replace(
        configs.get("flux_dit"),
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, head_dim=64, vocab_size=4096)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--out", default="experiments/e2e")
    args = ap.parse_args()

    arch = configs.get_reduced("flux_dit") if args.small else model_100m()
    lat_tok, lat_dim = (8, 8) if args.small else (64, 16)
    flow = FlowRLConfig(
        trainer_type="flow_grpo", sde_type="flow_sde", eta=0.7,
        num_steps=4 if args.small else 8,
        group_size=4, latent_tokens=lat_tok, latent_dim=lat_dim,
        advantage_agg="gdpo",
        rewards=(RewardSpec("text_render", 1.0,
                            args={"latent_dim": lat_dim,
                                  "latent_tokens": lat_tok}),
                 RewardSpec("pickscore", 0.25,
                            args={"latent_dim": lat_dim}),
                 RewardSpec("latent_norm", 0.1)))
    opt = OptimConfig(lr=3e-4, total_steps=args.steps,
                      warmup_steps=max(2, args.steps // 20))
    key = jax.random.PRNGKey(0)

    os.makedirs(args.out, exist_ok=True)
    prompts = synthetic_prompts(64)
    cache = PreprocessCache(os.path.join(args.out, "cache"))
    t0 = time.time()
    n = preprocess_dataset(prompts, cache)
    provider = ConditionProvider(preprocessing=True, cache=cache)
    print(f"[phase 1] preprocessed {n} prompts in {time.time()-t0:.1f}s; "
          "frozen encoders offloaded")

    trainer = registry.build("trainer", "flow_grpo", arch, flow, opt,
                             key=key)
    n_params = sum(x.size for x in jax.tree.leaves(trainer.state.params))
    print(f"[phase 2] Flow-GRPO on {arch.name} ({n_params/1e6:.1f}M params)")

    ds = PromptDataset(prompts, batch_size=4)
    log = []
    for it, bp in zip(range(args.steps), ds.infinite()):
        t_it = time.time()
        cond = provider.get(bp)["cond"]
        m = trainer.step(cond, key, it=it)
        log.append({"step": it, "reward": float(m["reward_mean"]),
                    "loss": float(m["loss"]),
                    "dt": round(time.time() - t_it, 2)})
        if it % 10 == 0 or it == args.steps - 1:
            print(f"  step {it:4d} reward={log[-1]['reward']:+.4f} "
                  f"dt={log[-1]['dt']}s")
        if (it + 1) % 100 == 0:
            checkpoint.save_checkpoint(os.path.join(args.out, "ckpt"),
                                       it + 1, trainer.state.params)
    with open(os.path.join(args.out, "reward_log.json"), "w") as f:
        json.dump(log, f)
    early = np.mean([r["reward"] for r in log[:5]])
    late = np.mean([r["reward"] for r in log[-5:]])
    print(f"[done] reward {early:+.4f} -> {late:+.4f} "
          f"({'improved' if late > early else 'no gain'})")


if __name__ == "__main__":
    main()
