"""Quickstart: the paper's registry workflow in ~40 lines.

Builds a flow-matching policy over any backbone in the zoo, picks an RL
algorithm + SDE dynamics + rewards purely by name, and runs a few training
iterations on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import configs, registry
from repro.config import FlowRLConfig, OptimConfig, RewardSpec

key = jax.random.PRNGKey(0)

# 1. pick a backbone (any of the 10 assigned archs or the paper's DiT)
arch = configs.get_reduced("flux_dit")

# 2. configure the run — every component is selected by registry name
flow = FlowRLConfig(
    trainer_type="flow_grpo",       # flow_grpo | mix_grpo | grpo_guard | nft | awm
    sde_type="flow_sde",            # flow_sde | dance_sde | cps | ode (Table 1)
    eta=0.7, num_steps=6, group_size=4,
    latent_tokens=8, latent_dim=8,
    advantage_agg="gdpo",           # weighted_sum | gdpo
    rewards=(
        RewardSpec("text_render", 1.0,
                   args={"latent_dim": 8, "latent_tokens": 8}),
        RewardSpec("latent_norm", 0.1),
    ))
opt = OptimConfig(lr=3e-4, total_steps=20, warmup_steps=2)

# 3. build the trainer from the registry and train
trainer = registry.build("trainer", flow.trainer_type, arch, flow, opt,
                         key=key)
cond = jax.random.normal(key, (2, 4, 512))   # 2 prompts' cached embeddings

for it in range(10):
    metrics = trainer.step(cond, key, it=it)
    print(f"step {it}: reward={float(metrics['reward_mean']):+.4f} "
          f"loss={float(metrics['loss']):+.4f}")

print("\nswap the algorithm with ONE config change:")
trainer2 = registry.build("trainer", "awm", arch, flow, opt, key=key)
m = trainer2.step(cond, key, it=0)
print(f"awm step 0: reward={float(m['reward_mean']):+.4f}")
