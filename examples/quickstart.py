"""Quickstart: one declarative config is the whole experiment.

``RunConfig`` names every component — backbone, RL algorithm, SDE dynamics,
rewards, dataset — by its registry name; ``Experiment`` resolves them and
runs the shared TrainLoop (paper §2.1: any model × algorithm × reward ×
scheduler combination from config alone, O(M+N) integration cost).
Swapping the algorithm is a one-override change, shown at the end.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Experiment, apply_overrides
from repro.config import (DataConfig, FlowRLConfig, LoopConfig, OptimConfig,
                          RewardSpec, RunConfig)

cfg = RunConfig(
    arch="flux_dit", reduced=True,          # any zoo arch, CPU-scale variant
    flow=FlowRLConfig(
        trainer_type="flow_grpo",           # registry.names("trainer")
        sde_type="flow_sde",                # registry.names("scheduler")
        eta=0.7, num_steps=6, group_size=4,
        latent_tokens=8, latent_dim=8,
        advantage_agg="gdpo",               # weighted_sum | gdpo
        rewards=(RewardSpec("text_render", 1.0),    # args auto-completed
                 RewardSpec("latent_norm", 0.1)),
        preprocessing=True, cache_dir="cache/quickstart"),
    optim=OptimConfig(lr=3e-4, total_steps=10, warmup_steps=2),
    data=DataConfig(n_prompts=16, batch_prompts=2,
                    encoder=dict(cond_dim=64, cond_len=4, vocab=512,
                                 hidden=128)),
    loop=LoopConfig(steps=10, log_every=1, save_every=0, resume=False))

result = Experiment.from_config(cfg).train()

print("\nswap the algorithm with ONE override:")
exp2 = Experiment.from_config(apply_overrides(cfg, ["flow.trainer_type=awm"]))
m = exp2.train()["history"][-1]
print(f"awm final step: reward={m['reward']:+.4f}")
