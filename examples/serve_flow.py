"""Serving example: batched flow-matching sampling with interchangeable
backbones and solvers — the inference half of the Experiment front door.

Generates latents for a batch of prompt requests with (a) the paper's DiT
and (b) an SSM backbone, under ODE and SDE solvers, and prints throughput.
Backbone and solver are registry names on the same config.

  PYTHONPATH=src python examples/serve_flow.py
"""
import time

import jax
import jax.numpy as jnp

from repro.api import Experiment
from repro.config import DataConfig, FlowRLConfig, RunConfig
from repro.data import synthetic_prompts

ENCODER = dict(cond_dim=512, cond_len=8, vocab=4096, hidden=256)


def make_exp(arch_name: str, sde: str) -> Experiment:
    return Experiment.from_config(RunConfig(
        arch=arch_name, reduced=True,
        flow=FlowRLConfig(sde_type=sde, eta=0.3, num_steps=6,
                          latent_tokens=8, latent_dim=8,
                          preprocessing=False),
        data=DataConfig(encoder=ENCODER)))


prompts = synthetic_prompts(8)
key = jax.random.PRNGKey(0)
# the condition embeddings don't depend on backbone or solver: encode once
cond = make_exp("flux_dit", "ode").build_provider(live=True) \
    .get(prompts)["cond"]

for arch_name in ("flux_dit", "mamba2-370m"):
    for sde in ("ode", "dance_sde"):
        exp = make_exp(arch_name, sde)
        sampler = exp.build_sampler(key, max_batch=4)
        sampler.serve(cond, key)                     # compile
        t0 = time.perf_counter()
        lat = sampler.serve(cond, key)
        jax.block_until_ready(lat)
        dt = time.perf_counter() - t0
        rms = float(jnp.sqrt((lat ** 2).mean()))
        print(f"{arch_name:14s} solver={sde:10s} "
              f"{len(prompts)/dt:6.1f} req/s  latent_rms={rms:.3f}")
