"""Serving example: the bucketed continuous-batching engine with
interchangeable backbones and solvers — the inference half of the
Experiment front door.

For each backbone × solver combination the engine is warmed (bucket grid
pre-traced, compile time reported separately), then a mixed request load —
including repeat prompts, which hit the cond-encoding cache — is served
and steady-state throughput printed.  Backbone and solver are registry
names on the same config.

  PYTHONPATH=src python examples/serve_flow.py
"""
import time

import jax
import numpy as np

from repro.api import Experiment
from repro.config import DataConfig, FlowRLConfig, RunConfig
from repro.data import synthetic_prompts

ENCODER = dict(cond_dim=512, cond_len=8, vocab=4096, hidden=256)


def make_exp(arch_name: str, sde: str) -> Experiment:
    return Experiment.from_config(RunConfig(
        arch=arch_name, reduced=True,
        flow=FlowRLConfig(sde_type=sde, eta=0.3, num_steps=6,
                          latent_tokens=8, latent_dim=8,
                          preprocessing=False),
        data=DataConfig(encoder=ENCODER)))


# a mixed load: 6 unique prompts, 2 repeats (cond-cache hits)
prompts = synthetic_prompts(6) + synthetic_prompts(2)
key = jax.random.PRNGKey(0)

for arch_name in ("flux_dit", "mamba2-370m"):
    for sde in ("ode", "dance_sde"):
        exp = make_exp(arch_name, sde)
        engine = exp.build_engine(key, max_batch=4)
        t0 = time.perf_counter()
        engine.warmup()                              # pre-trace bucket grid
        engine.encode(prompts)                       # prime encoder + cache
        warm = time.perf_counter() - t0
        t0 = time.perf_counter()
        lat = engine.serve(prompts, key)
        jax.block_until_ready(lat)
        dt = time.perf_counter() - t0
        s = engine.stats
        # already synced by block_until_ready — compute the report on host
        # instead of paying a second device round-trip (jaxlint R002)
        rms = float(np.sqrt((np.asarray(lat) ** 2).mean()))
        print(f"{arch_name:14s} solver={sde:10s} "
              f"{len(prompts)/dt:6.1f} req/s (warmup {warm:4.1f}s)  "
              f"latent_rms={rms:.3f}  buckets={s['buckets']} "
              f"cache_hits={s['cond_cache']['hits']}")
        assert s["cold_dispatches"] == 0
