"""Serving example: batched flow-matching sampling with interchangeable
backbones and solvers — the inference half of the framework.

Generates latents for a batch of prompt requests with (a) the paper's DiT
and (b) an SSM backbone, under ODE and SDE solvers, and prints throughput.

  PYTHONPATH=src python examples/serve_flow.py
"""
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import FlowRLConfig
from repro.core.preprocess import ConditionProvider
from repro.data import synthetic_prompts
from repro.launch.serve import FlowSampler

key = jax.random.PRNGKey(0)
provider = ConditionProvider(preprocessing=False,
                             encoder_kw=dict(cond_dim=512, cond_len=8,
                                             vocab=4096, hidden=256))
prompts = synthetic_prompts(8)
cond = provider.get(prompts)["cond"]

for arch_name in ("flux_dit", "mamba2-370m"):
    for sde in ("ode", "dance_sde"):
        flow = FlowRLConfig(sde_type=sde, eta=0.3, num_steps=6,
                            latent_tokens=8, latent_dim=8)
        sampler = FlowSampler(configs.get_reduced(arch_name), flow,
                              key=key, max_batch=4)
        lat = sampler.serve(cond, key)           # compile
        t0 = time.perf_counter()
        lat = sampler.serve(cond, key)
        jax.block_until_ready(lat)
        dt = time.perf_counter() - t0
        rms = float(jnp.sqrt((lat ** 2).mean()))
        print(f"{arch_name:14s} solver={sde:10s} "
              f"{len(prompts)/dt:6.1f} req/s  latent_rms={rms:.3f}")
