"""``ServingEngine`` — multi-tenant request-queue serving with bucketed
continuous batching, admission control, compile-cache warmup, cond-encoding
cache, and sharded inference.

Architecture (the production path the ROADMAP north star asks for):

* **Requests**, not arrays, are the unit of work: ``submit()`` enqueues a
  (cond, key, num_steps) request under a (tenant, priority class) and
  returns a handle; full buckets dispatch as in-flight slots allow
  (continuous batching — a full batch never waits for the deadline),
  partial buckets flush when the oldest request crosses its dispatch
  deadline (``poll``) or on ``drain()``.
* **Multi-tenancy** (:mod:`repro.serving.admission`): priority classes
  with weighted-fair stride scheduling across tenants, per-request SLO
  deadlines (``slo_s``, or the class default), and admission control —
  each class's queue depth is bounded, and an over-capacity ``submit()``
  raises :class:`repro.serving.admission.RetryAfter` (a structured,
  JSON-ready rejection with a deterministic ``retry_after_s``) instead of
  queueing unboundedly.  ``max_inflight`` bounds dispatched-but-unfetched
  batches, so backpressure propagates from slow consumers to rejections,
  not to memory growth: deadline flushes bypass the cap only through a
  bounded emergency window (at most ``2 * max_inflight`` per ``poll``),
  and a batch whose handles are abandoned without being fetched retires
  its slot on GC, so the window cannot leak shut.
* **Shape buckets** bound jit recompiles on BOTH axes: batches are padded
  up to a fixed tier ladder (:class:`repro.serving.buckets.BucketGrid`)
  and ``num_steps`` is admitted only from the step-tier grid
  (:class:`repro.serving.buckets.StepGrid`), so ``warmup()`` pre-traces
  the whole (bucket × step tier) grid and steady-state serving *provably*
  never compiles.  Padding is correct, not just safe, because execution
  uses the per-request-keyed rollout (:func:`repro.core.rollout
  .rollout_keyed`): each request's latent is a pure function of its own
  (cond, key), bit-identical across bucket sizes, batch mates, scheduling
  order, and device layouts.
* **Cond-encoding cache**: repeat prompts skip the ConditionProvider (an
  LRU keyed by prompt string) — the serving-side analogue of the paper's
  §2.2 preprocessing cache.
* **Sharded inference** reuses ``repro.distributed``'s 2-D mesh: with a
  mesh, execution goes through ``make_rollout_keyed_sharded`` (cond and
  per-request keys both batch-sharded, no axis-index key folds), so
  ``dist.data_parallel=N`` serves N-way today on faked CPU devices and on
  real accelerators unchanged — with output bit-identical per request to
  single-device.  With ``dist.model_parallel>1`` the executor consumes the
  trainer's :class:`repro.distributed.PartitionPlan` (params stay
  model-sharded end to end; outputs are f32-rounding-equal rather than
  bit-identical — see ``make_rollout_keyed_sharded``).

``engine.stats`` is a JSON-serializable health snapshot (queue depths,
rejections, SLO misses, dispatch/compile accounting) consumed by
``launch/serve.py --stats-json``.  Trainers can opt their online rollouts
into the same engine (``BaseTrainer.attach_engine``):
``ServingEngine.rollout`` returns full :class:`Trajectory` batches
(capacity-chunked, bucket-padded, unpadded on the way out), sharing the
compile cache with the serving path.
"""
from __future__ import annotations

import itertools
import math
import time
import weakref
from collections import OrderedDict
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import distributed
from repro.core.rollout import Trajectory, request_keys
from repro.serving.admission import (AdmissionConfig, AdmissionController,
                                     RetryAfter)
from repro.serving.buckets import BucketGrid, StepGrid

# distinct auto-key stream per engine instance: auto keys are
# fold_in(fold_in(BASE, engine_seq), rid), which collides neither with
# user PRNGKey(seed) submissions nor with another engine's auto keys
_AUTO_KEY_BASE = 0x466C6F77            # "Flow"
_ENGINE_SEQ = itertools.count()
# auto keys are fetched to host in blocks of this many rids: one device
# round-trip amortized over the block, not one per submit
_AUTO_KEY_BLOCK = 256


class _BatchResult:
    """Shared result holder for one dispatched bucket: keeps the device
    array unmaterialized (dispatches stay async — the next batch's queue
    work overlaps this one's compute) and pays the device->host copy once
    per BATCH on first access, never per request.  The batch's in-flight
    slot retires on materialization OR on GC, whichever comes first
    (``weakref.finalize``): a client that abandons its handles after
    dispatch (timeout, disconnect) must not pin a ``max_inflight`` slot
    forever."""

    __slots__ = ("_dev", "_np", "_retire", "__weakref__")

    def __init__(self, x0_dev: jax.Array,
                 on_materialize: Optional[Callable[[], None]] = None):
        self._dev = x0_dev
        self._np: Optional[np.ndarray] = None
        if on_materialize is None:
            self._retire = None
        else:
            cell = [on_materialize]

            def retire_once():
                if cell:
                    cell.pop()()

            self._retire = retire_once
            # the callback closes over the cell, never over self — a
            # finalizer referencing its own object would keep it alive
            weakref.finalize(self, retire_once)

    def row(self, i: int) -> np.ndarray:
        if self._np is None:
            self._np = np.asarray(self._dev)
            self._dev = None
            if self._retire is not None:
                self._retire()
        return self._np[i]


class Request:
    """One enqueued sampling request; doubles as its result handle.

    cond/key/result live host-side (numpy): per-row device slicing costs
    ~ms per op on the queue path, so the engine crosses the device boundary
    exactly twice per *dispatch* (one device_put in, one lazy copy out),
    never per request.  ``deadline`` is the dispatch-by time (batching
    flush deadline or SLO deadline, whichever is sooner); ``slo_deadline``
    is the completion target used for SLO-miss accounting."""

    __slots__ = ("rid", "cond", "key", "num_steps", "arrival", "tenant",
                 "priority", "deadline", "slo_deadline", "_result")

    def __init__(self, rid: int, cond: np.ndarray, key: np.ndarray,
                 num_steps: int, arrival: float, *,
                 tenant: str = "default", priority: str = "standard",
                 deadline: float = math.inf,
                 slo_deadline: float = math.inf):
        self.rid = rid
        self.cond = cond
        self.key = key
        self.num_steps = num_steps
        self.arrival = arrival
        self.tenant = tenant
        self.priority = priority
        self.deadline = deadline
        self.slo_deadline = slo_deadline
        self._result: Optional[tuple] = None        # (_BatchResult, row)

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> np.ndarray:
        if self._result is None:
            raise RuntimeError(
                f"request {self.rid} has not been served yet — call "
                "engine.poll() past its deadline or engine.drain()")
        holder, row = self._result
        return holder.row(row)


class CondCache:
    """LRU prompt -> condition-embedding cache (repeat prompts skip the
    ConditionProvider entirely)."""

    def __init__(self, max_entries: int = 1024):
        self.max_entries = max_entries
        self._store: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, prompt: str) -> Optional[np.ndarray]:
        cond = self._store.get(prompt)
        if cond is None:
            self.misses += 1
            return None
        self._store.move_to_end(prompt)
        self.hits += 1
        return cond

    def put(self, prompt: str, cond: np.ndarray) -> None:
        self._store[prompt] = cond
        self._store.move_to_end(prompt)
        while len(self._store) > self.max_entries:
            self._store.popitem(last=False)

    def __len__(self) -> int:
        return len(self._store)


class ServingEngine:
    """Bucketed continuous-batching inference over a FlowAdapter.

    ``params`` may be None for the trainer-rollout path (params are then
    passed per :meth:`rollout` call); the queue path (:meth:`submit` /
    :meth:`serve`) requires them at construction.

    ``step_tiers`` is the admitted ``num_steps`` quality ladder (always
    including ``num_steps`` itself); ``admission`` configures priority
    classes / tenant weights / queue bounds; ``max_inflight`` bounds
    dispatched-but-unfetched batches (the backpressure window).
    """

    def __init__(self, adapter, scheduler, params=None, *,
                 num_steps: int, max_batch: int = 8,
                 buckets: Optional[Sequence[int]] = None,
                 step_tiers: Optional[Sequence[int]] = None,
                 deadline_s: float = 0.005,
                 admission: Optional[AdmissionConfig] = None,
                 max_inflight: int = 4,
                 mesh=None, plan=None, provider=None, cond_len: int = 16,
                 cond_cache_entries: int = 1024,
                 clock: Callable[[], float] = time.monotonic):
        if max_inflight < 1:
            raise ValueError(
                f"max_inflight must be >= 1, got {max_inflight}")
        self.adapter = adapter
        self.scheduler = scheduler
        self.params = params
        self.steps = StepGrid(step_tiers, default=num_steps)
        self.num_steps = num_steps
        self.deadline_s = deadline_s
        self.max_inflight = max_inflight
        self.mesh = mesh
        # the PartitionPlan is only consulted when the mesh has a model
        # axis (the mp=1 shard_map path takes replicated params); self-build
        # one from the adapter's spec if the caller did not hand one over
        if plan is None and distributed.mesh_mp(mesh) > 1:
            plan = distributed.partition_plan(mesh, adapter.spec())
        self.plan = plan
        self.provider = provider
        self.cond_len = cond_len
        self.clock = clock
        dp = distributed.mesh_dp(mesh)
        self.grid = BucketGrid(buckets, max_batch=max_batch, dp=dp)
        self.admission = AdmissionController(admission)
        self.cond_cache = CondCache(cond_cache_entries)
        # one-time constructor sync, not a hot path: submit() reads auto
        # keys from host-side blocks (_auto_key, one fetch per
        # _AUTO_KEY_BLOCK rids), never folding on-device per request
        self._base_key = np.asarray(jax.random.fold_in(  # jaxlint: disable=R002 — one-time __init__ fetch; the queue path reads precomputed host blocks
            jax.random.PRNGKey(_AUTO_KEY_BASE), next(_ENGINE_SEQ)))
        self._auto_keys: Optional[np.ndarray] = None   # block cache ...
        self._auto_start = 0                           # ... starts at rid
        # one jitted executor per (num_steps, x0_only) tier; jit's shape
        # cache then holds one executable per bucket size underneath it.
        # The queue path uses the x0-only variant (XLA drops the stacked
        # trajectory buffers); trainer rollouts get the full Trajectory.
        self._fns: Dict[tuple, Callable] = {}
        self._masks: Dict[int, jax.Array] = {}
        self._traced: set = set()          # (bucket, num_steps) ever run
        self._warmed: set = set()          # (bucket, num_steps) pre-traced
        self._inflight = 0
        self._next_rid = 0
        self.counters: Dict[str, Any] = {
            "requests": 0, "dispatches": {}, "padded_lanes": 0,
            "compiles": 0, "cold_dispatches": 0, "warmup_s": 0.0,
            "served_by_class": {}, "served_by_tenant": {},
            "slo_misses": {},
        }

    # ---------------------------------------------------------- construction
    @classmethod
    def for_trainer(cls, trainer, **kw) -> "ServingEngine":
        """Engine sharing a trainer's adapter/scheduler/num_steps/mesh —
        the object to pass to ``trainer.attach_engine``.  ``max_batch``
        caps the rollout chunk size (memory bound); batches larger than it
        run in capacity-sized slices."""
        kw.setdefault("plan", getattr(trainer, "plan", None))
        return cls(trainer.adapter, trainer.scheduler,
                   num_steps=trainer.flow.num_steps, mesh=trainer.mesh, **kw)

    # -------------------------------------------------------------- encoding
    def encode(self, prompts: Sequence[str]) -> np.ndarray:
        """(N, Lc, D) condition embeddings (host-side), LRU-cached per
        prompt; misses are encoded in ONE ConditionProvider batch."""
        if self.provider is None:
            raise ValueError(
                "this engine has no ConditionProvider — submit cond "
                "embeddings directly or construct with provider=...")
        out: Dict[int, np.ndarray] = {}
        miss_rows: Dict[str, List[int]] = {}     # unique prompt -> indices
        for i, p in enumerate(prompts):
            if p in miss_rows:                   # in-batch duplicate: skips
                miss_rows[p].append(i)           # the provider => a hit
                self.cond_cache.hits += 1
                continue
            cached = self.cond_cache.get(p)
            if cached is None:
                miss_rows[p] = [i]
            else:
                out[i] = cached
        if miss_rows:
            fresh = np.asarray(
                self.provider.get(list(miss_rows))["cond"])
            for j, (p, rows) in enumerate(miss_rows.items()):
                # .copy(): a cached row must not be a view pinning the
                # whole miss-batch array in memory past LRU eviction
                self.cond_cache.put(p, fresh[j].copy())
                for i in rows:
                    out[i] = fresh[j]
        return np.stack([out[i] for i in range(len(prompts))])

    # ----------------------------------------------------------------- queue
    def submit(self, cond=None, *, prompt: Optional[str] = None,
               key: Optional[jax.Array] = None, seed: Optional[int] = None,
               num_steps: Optional[int] = None, tenant: str = "default",
               priority: Optional[str] = None,
               slo_s: Optional[float] = None) -> Request:
        """Enqueue one request; returns its handle.  The request's latent is
        fully determined by (cond, key, num_steps) — the same key always
        yields the same latent, whatever batch, tenant mix, or scheduling
        order it lands in.

        Raises :class:`repro.serving.admission.RetryAfter` (structured,
        JSON-ready, with a ``retry_after_s`` hint) when the priority
        class's queue is at its depth bound, and ``ValueError`` for
        off-grid ``num_steps`` or a cond shape outside the warmed grid —
        both would otherwise compile on the hot path."""
        if (cond is None) == (prompt is None):
            raise ValueError("submit exactly one of cond= or prompt=")
        if cond is None:
            cond = self.encode([prompt])[0]
        cond = np.asarray(cond)
        expect = (self.cond_len, self.adapter.cond_dim)
        if cond.shape != expect:
            raise ValueError(
                f"request cond must be (Lc, cond_dim) = {expect} — the "
                f"shape the compile grid is warmed for — got {cond.shape}")
        steps = self._resolve_steps(num_steps)
        cls = self.admission.resolve_class(priority)
        if key is None:
            if seed is not None:
                key = jax.random.PRNGKey(seed)
            else:
                # fold_in from the per-engine base key: never collides
                # with a user PRNGKey(seed) and never repeats across
                # engine instances (PRNGKey(rid) did both)
                key = self._auto_key(self._next_rid)
        key = np.asarray(key)
        if slo_s is not None and slo_s <= 0:
            raise ValueError(f"slo_s must be > 0, got {slo_s}")
        slo = slo_s if slo_s is not None else cls.slo_s
        now = self.clock()
        slo_deadline = now + slo if slo is not None else math.inf
        req = Request(self._next_rid, cond, key, steps, now,
                      tenant=tenant, priority=cls.name,
                      deadline=min(now + self.deadline_s, slo_deadline),
                      slo_deadline=slo_deadline)
        self.admission.admit(req, now)     # may raise RetryAfter
        self._next_rid += 1
        self.counters["requests"] += 1
        self._pump(now)
        return req

    def _auto_key(self, rid: int) -> np.ndarray:
        """Auto key for ``rid``: ``fold_in(base_key, rid)``, served from a
        host-side block precomputed ``_AUTO_KEY_BLOCK`` rids at a time —
        one device round-trip per block, zero on the per-submit path."""
        if (self._auto_keys is None
                or not (self._auto_start <= rid
                        < self._auto_start + len(self._auto_keys))):
            base = jnp.asarray(self._base_key)
            rids = jnp.arange(rid, rid + _AUTO_KEY_BLOCK, dtype=jnp.uint32)
            self._auto_keys = np.asarray(  # jaxlint: disable=R002 — one fetch per _AUTO_KEY_BLOCK submits, amortized off the hot path
                jax.vmap(lambda r: jax.random.fold_in(base, r))(rids))
            self._auto_start = rid
        return self._auto_keys[rid - self._auto_start]

    def _pump(self, now: float) -> int:
        """Continuous batching under backpressure: dispatch full buckets
        while in-flight slots allow.  Returns requests dispatched."""
        n = 0
        while self._inflight < self.max_inflight:
            tier = next((s for s in self.admission.tiers()
                         if self.admission.ready(s) >= self.grid.capacity),
                        None)
            if tier is None:
                break
            batch = self.admission.take(tier, self.grid.capacity, now)
            self._dispatch(batch)
            n += len(batch)
        return n

    def poll(self) -> int:
        """Flush queues holding a request past its dispatch deadline (the
        batching flush deadline or its SLO deadline, whichever came
        first) — deadline flushes bypass the in-flight cap, but through a
        *bounded* emergency window: at most ``2 * max_inflight`` deadline
        dispatches per call, so a burst of expired deadlines (slow
        consumer + short SLOs) drains over successive polls instead of
        materializing unbounded in-flight device batches at once.  Then
        dispatch any full buckets the freed queues allow.  Returns
        requests dispatched."""
        now = self.clock()
        n = 0
        flushes, flush_cap = 0, 2 * self.max_inflight
        for steps in list(self.admission.tiers()):
            while (flushes < flush_cap
                   and self.admission.has_expired(steps, now)):
                batch = self.admission.take(steps, self.grid.capacity, now)
                self._dispatch(batch)
                flushes += 1
                n += len(batch)
        n += self._pump(now)
        return n

    def drain(self) -> int:
        """Dispatch everything still queued, deadline or not."""
        now = self.clock()
        n = 0
        for steps in list(self.admission.tiers()):
            while self.admission.ready(steps):
                batch = self.admission.take(steps, self.grid.capacity, now)
                self._dispatch(batch)
                n += len(batch)
        return n

    def pending(self) -> int:
        return self.admission.pending()

    # ------------------------------------------------------------- execution
    def _resolve_steps(self, num_steps: Optional[int]) -> int:
        return self.steps.resolve(num_steps)

    def _account(self, bucket: int, num_steps: int, n_real: int,
                 x0_only: bool) -> None:
        """Single home of the dispatch bookkeeping (queue + rollout paths):
        compile-cache tracking and the dispatch/padding counters.  Trace
        shapes are keyed by (bucket, steps, x0_only) because the two
        executor variants compile separately — warmup covers the queue
        (x0_only) variant, so a trainer-path rollout at the same (bucket,
        steps) is still, correctly, a cold compile."""
        self._note_trace((bucket, num_steps, x0_only))
        d = self.counters["dispatches"]
        d[(bucket, num_steps)] = d.get((bucket, num_steps), 0) + 1
        self.counters["padded_lanes"] += bucket - n_real

    def _note_trace(self, shape, during_warmup: bool = False) -> None:
        if shape in self._traced:
            return
        self._traced.add(shape)
        self.counters["compiles"] += 1
        if not during_warmup and shape not in self._warmed:
            self.counters["cold_dispatches"] += 1

    def _fn(self, num_steps: int, x0_only: bool = False) -> Callable:
        fn = self._fns.get((num_steps, x0_only))
        if fn is None:
            fn = distributed.make_rollout_keyed_sharded(
                self.adapter, self.scheduler, num_steps, self.mesh,
                x0_only=x0_only, plan=self.plan)
            self._fns[(num_steps, x0_only)] = fn
        return fn

    def _mask(self, num_steps: int) -> jax.Array:
        mask = self._masks.get(num_steps)
        if mask is None:
            mask = self._masks[num_steps] = jnp.ones((num_steps,), bool)
        return mask

    def _execute(self, cond, keys, num_steps: int) -> jax.Array:
        """Run one bucket-shaped batch -> (bucket, Lt, ld) latents
        (accounting is the caller's job)."""
        return self._fn(num_steps, x0_only=True)(
            self.params, cond, keys, self._mask(num_steps))

    def _pad(self, arr: jax.Array, bucket: int) -> jax.Array:
        pad = bucket - arr.shape[0]
        if not pad:
            return arr
        xp = np if isinstance(arr, np.ndarray) else jnp
        return xp.concatenate(
            [arr, xp.zeros((pad,) + arr.shape[1:], arr.dtype)])

    def _retire_inflight(self) -> None:
        self._inflight -= 1
        # a freed slot may unblock a queued full bucket right away
        self._pump(self.clock())

    def _dispatch(self, batch: List[Request]) -> None:
        if self.params is None:
            raise RuntimeError(
                "engine has no params — pass params= at construction for "
                "the queue path (or use engine.rollout for trainers)")
        steps = batch[0].num_steps
        bucket = self.grid.pick(len(batch))
        self._account(bucket, steps, len(batch), x0_only=True)
        now = self.clock()
        served_c = self.counters["served_by_class"]
        served_t = self.counters["served_by_tenant"]
        misses = self.counters["slo_misses"]
        for r in batch:
            served_c[r.priority] = served_c.get(r.priority, 0) + 1
            served_t[r.tenant] = served_t.get(r.tenant, 0) + 1
            if now > r.slo_deadline:
                misses[r.priority] = misses.get(r.priority, 0) + 1
        cond = self._pad(np.stack([r.cond for r in batch]), bucket)
        keys = self._pad(np.stack([r.key for r in batch]), bucket)
        self._inflight += 1
        holder = _BatchResult(self._execute(cond, keys, steps),
                              on_materialize=self._retire_inflight)
        for i, r in enumerate(batch):
            r._result = (holder, i)

    # ----------------------------------------------------------- conveniences
    def serve(self, requests: Union[Sequence[str], jax.Array],
              key: Optional[jax.Array] = None,
              num_steps: Optional[int] = None, *,
              tenant: str = "default",
              priority: Optional[str] = None) -> jax.Array:
        """Synchronous batch serve: prompts (via the cond cache) or a
        (N, Lc, D) cond array -> (N, Lt, ld) latents.  Request i's key is
        ``fold_in(key, i)`` — per-request results are independent of N,
        bucket layout, and max_batch.

        The caller IS the consumer here, so serve() drives its own queue:
        when admission pushes back (:class:`RetryAfter`), it flushes the
        backlog and materializes finished batches (retiring their
        in-flight slots) before resubmitting — any N serves under the
        same bounded queues and bounded device memory as the async path,
        with no handle ever abandoned."""
        if key is None:
            key = jax.random.PRNGKey(0)
        if len(requests) == 0:
            fc = self.adapter.flow_cfg
            return jnp.zeros((0, fc.latent_tokens, fc.latent_dim),
                             jnp.float32)
        if isinstance(requests[0], str):
            cond = self.encode(list(requests))
        else:
            cond = np.asarray(requests)
        keys = np.asarray(request_keys(key, cond.shape[0]))
        handles: List[Request] = []
        for i in range(cond.shape[0]):
            while True:
                try:
                    handles.append(self.submit(
                        cond=cond[i], key=keys[i], num_steps=num_steps,
                        tenant=tenant, priority=priority))
                    break
                except RetryAfter:
                    # full queue + full in-flight window: dispatch the
                    # backlog, then materialize what finished so slots
                    # retire and the resubmit is admitted
                    self.drain()
                    for h in handles:
                        if h.done:
                            h.result()
        self.drain()
        return jnp.asarray(np.stack([h.result() for h in handles]))

    def rollout(self, params, cond: jax.Array, key: jax.Array,
                sde_mask: Optional[jax.Array] = None,
                num_steps: Optional[int] = None) -> Trajectory:
        """Trainer-facing batched rollout through the engine's compile
        cache: per-request keys (fold_in(key, i)), capacity-sized chunks,
        bucket padding in, exact-size Trajectory out."""
        steps = self._resolve_steps(num_steps)
        if sde_mask is None:
            sde_mask = jnp.ones((steps,), bool)
        B = cond.shape[0]
        keys = request_keys(key, B)
        cap = self.grid.capacity
        chunks: List[Trajectory] = []
        for i in range(0, B, cap):
            c, k = cond[i:i + cap], keys[i:i + cap]
            n = c.shape[0]
            bucket = self.grid.pick(n)
            self._account(bucket, steps, n, x0_only=False)
            traj = self._fn(steps)(params, self._pad(c, bucket),
                                   self._pad(k, bucket), sde_mask)
            chunks.append(Trajectory(
                xs=traj.xs[:, :n], logps=traj.logps[:, :n], ts=traj.ts,
                sde_mask=traj.sde_mask, cond=traj.cond[:n]))
        if len(chunks) == 1:
            return chunks[0]
        return Trajectory(
            xs=jnp.concatenate([t.xs for t in chunks], axis=1),
            logps=jnp.concatenate([t.logps for t in chunks], axis=1),
            ts=chunks[0].ts, sde_mask=chunks[0].sde_mask,
            cond=jnp.concatenate([t.cond for t in chunks], axis=0))

    # ---------------------------------------------------------------- warmup
    def warmup(self, num_steps_tiers: Optional[Sequence[int]] = None,
               params=None) -> Dict[str, float]:
        """Pre-trace the full (bucket × step tier) grid so steady-state
        serving never compiles — by default every tier in ``step_tiers``
        (submit admits nothing outside it).  Returns per-shape
        trace+first-run seconds; the total also lands in
        ``counters['warmup_s']``."""
        params = params if params is not None else self.params
        if params is None:
            raise RuntimeError("warmup needs params")
        tiers = sorted(set(num_steps_tiers or self.steps.sizes))
        report: Dict[str, float] = {}
        for steps in tiers:
            for bucket in self.grid.sizes:
                cond = np.zeros((bucket, self.cond_len,
                                 self.adapter.cond_dim), np.float32)
                keys = np.zeros((bucket, 2), np.uint32)
                t0 = time.perf_counter()
                x0 = self._fn(steps, x0_only=True)(params, cond, keys,
                                                   self._mask(steps))
                jax.block_until_ready(x0)
                dt = time.perf_counter() - t0
                report[f"b{bucket}/s{steps}"] = dt
                self._warmed.add((bucket, steps, True))
                self._note_trace((bucket, steps, True), during_warmup=True)
        self.counters["warmup_s"] += sum(report.values())
        return report

    # ----------------------------------------------------------------- stats
    @staticmethod
    def _shape_label(shape: tuple) -> str:
        bucket, steps, x0_only = shape
        return f"b{bucket}/s{steps}" + ("" if x0_only else "/traj")

    @property
    def stats(self) -> Dict[str, Any]:
        """JSON-serializable stats/health snapshot (``json.dumps`` safe —
        the health endpoint contract; tuple keys are stringified as
        ``"b<bucket>/s<steps>"``)."""
        c = self.counters
        return {
            "requests": c["requests"],
            "pending": self.pending(),
            "inflight": self._inflight,
            "max_inflight": self.max_inflight,
            "dispatches": {f"b{b}/s{s}": n
                           for (b, s), n in sorted(c["dispatches"].items())},
            "padded_lanes": c["padded_lanes"],
            "compiled_shapes": [self._shape_label(s)
                                for s in sorted(self._traced)],
            "warmed_shapes": [self._shape_label(s)
                              for s in sorted(self._warmed)],
            "compiles": c["compiles"],
            "cold_dispatches": c["cold_dispatches"],
            "warmup_s": c["warmup_s"],
            "priorities": self.admission.snapshot(),
            "served_by_class": dict(c["served_by_class"]),
            "served_by_tenant": dict(c["served_by_tenant"]),
            "slo_misses": dict(c["slo_misses"]),
            "cond_cache": {"hits": self.cond_cache.hits,
                           "misses": self.cond_cache.misses,
                           "entries": len(self.cond_cache)},
            "buckets": list(self.grid.sizes),
            "step_tiers": list(self.steps.sizes),
            "data_parallel": distributed.mesh_dp(self.mesh),
            "model_parallel": distributed.mesh_mp(self.mesh),
        }
