"""``ServingEngine`` — request-queue serving with bucketed continuous
batching, compile-cache warmup, cond-encoding cache, and sharded inference.

Architecture (the production path the ROADMAP north star asks for):

* **Requests**, not arrays, are the unit of work: ``submit()`` enqueues a
  (cond, key, num_steps) request and returns a handle; full buckets
  dispatch immediately (continuous batching — a full batch never waits),
  partial buckets flush when the oldest request crosses the deadline
  (``poll``) or on ``drain()``.
* **Shape buckets** bound jit recompiles: batches are padded up to a fixed
  tier ladder (:class:`repro.serving.buckets.BucketGrid`), and ``warmup()``
  pre-traces the whole (bucket × num_steps) grid so steady-state serving
  never compiles.  Padding is *correct*, not just safe, because execution
  uses the per-request-keyed rollout (:func:`repro.core.rollout
  .rollout_keyed`): each request's latent is a pure function of its own
  (cond, key), bit-identical across bucket sizes, batch mates, and device
  layouts.
* **Cond-encoding cache**: repeat prompts skip the ConditionProvider (an
  LRU keyed by prompt string) — the serving-side analogue of the paper's
  §2.2 preprocessing cache.
* **Sharded inference** reuses ``repro.distributed``'s "data" mesh: with a
  mesh, execution goes through ``make_rollout_keyed_sharded`` (cond and
  per-request keys both batch-sharded, no axis-index key folds), so
  ``dist.data_parallel=N`` serves N-way today on faked CPU devices and on
  real accelerators unchanged — with output bit-identical per request to
  single-device.

Trainers can opt their online rollouts into the same engine
(``BaseTrainer.attach_engine``): ``ServingEngine.rollout`` returns full
:class:`Trajectory` batches (capacity-chunked, bucket-padded, unpadded on
the way out), sharing the compile cache with the serving path.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import distributed
from repro.core.rollout import Trajectory, request_keys
from repro.serving.buckets import BucketGrid


class _BatchResult:
    """Shared result holder for one dispatched bucket: keeps the device
    array unmaterialized (dispatches stay async — the next batch's queue
    work overlaps this one's compute) and pays the device->host copy once
    per BATCH on first access, never per request."""

    __slots__ = ("_dev", "_np")

    def __init__(self, x0_dev: jax.Array):
        self._dev = x0_dev
        self._np: Optional[np.ndarray] = None

    def row(self, i: int) -> np.ndarray:
        if self._np is None:
            self._np = np.asarray(self._dev)
            self._dev = None
        return self._np[i]


class Request:
    """One enqueued sampling request; doubles as its result handle.

    cond/key/result live host-side (numpy): per-row device slicing costs
    ~ms per op on the queue path, so the engine crosses the device boundary
    exactly twice per *dispatch* (one device_put in, one lazy copy out),
    never per request."""

    __slots__ = ("rid", "cond", "key", "num_steps", "arrival", "_result")

    def __init__(self, rid: int, cond: np.ndarray, key: np.ndarray,
                 num_steps: int, arrival: float):
        self.rid = rid
        self.cond = cond
        self.key = key
        self.num_steps = num_steps
        self.arrival = arrival
        self._result: Optional[tuple] = None        # (_BatchResult, row)

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> np.ndarray:
        if self._result is None:
            raise RuntimeError(
                f"request {self.rid} has not been served yet — call "
                "engine.poll() past its deadline or engine.drain()")
        holder, row = self._result
        return holder.row(row)


class CondCache:
    """LRU prompt -> condition-embedding cache (repeat prompts skip the
    ConditionProvider entirely)."""

    def __init__(self, max_entries: int = 1024):
        self.max_entries = max_entries
        self._store: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, prompt: str) -> Optional[np.ndarray]:
        cond = self._store.get(prompt)
        if cond is None:
            self.misses += 1
            return None
        self._store.move_to_end(prompt)
        self.hits += 1
        return cond

    def put(self, prompt: str, cond: np.ndarray) -> None:
        self._store[prompt] = cond
        self._store.move_to_end(prompt)
        while len(self._store) > self.max_entries:
            self._store.popitem(last=False)

    def __len__(self) -> int:
        return len(self._store)


class ServingEngine:
    """Bucketed continuous-batching inference over a FlowAdapter.

    ``params`` may be None for the trainer-rollout path (params are then
    passed per :meth:`rollout` call); the queue path (:meth:`submit` /
    :meth:`serve`) requires them at construction.
    """

    def __init__(self, adapter, scheduler, params=None, *,
                 num_steps: int, max_batch: int = 8,
                 buckets: Optional[Sequence[int]] = None,
                 deadline_s: float = 0.005,
                 mesh=None, provider=None, cond_len: int = 16,
                 cond_cache_entries: int = 1024,
                 clock: Callable[[], float] = time.monotonic):
        if num_steps < 1:
            raise ValueError(f"num_steps must be >= 1, got {num_steps}")
        self.adapter = adapter
        self.scheduler = scheduler
        self.params = params
        self.num_steps = num_steps
        self.deadline_s = deadline_s
        self.mesh = mesh
        self.provider = provider
        self.cond_len = cond_len
        self.clock = clock
        dp = 1 if mesh is None else mesh.shape[distributed.DATA_AXIS]
        self.grid = BucketGrid(buckets, max_batch=max_batch, dp=dp)
        self.cond_cache = CondCache(cond_cache_entries)
        # one jitted executor per (num_steps, x0_only) tier; jit's shape
        # cache then holds one executable per bucket size underneath it.
        # The queue path uses the x0-only variant (XLA drops the stacked
        # trajectory buffers); trainer rollouts get the full Trajectory.
        self._fns: Dict[tuple, Callable] = {}
        self._masks: Dict[int, jax.Array] = {}
        self._traced: set = set()          # (bucket, num_steps) ever run
        self._warmed: set = set()          # (bucket, num_steps) pre-traced
        self._queues: Dict[int, deque] = {}
        self._next_rid = 0
        self.counters: Dict[str, Any] = {
            "requests": 0, "dispatches": {}, "padded_lanes": 0,
            "compiles": 0, "cold_dispatches": 0, "warmup_s": 0.0,
        }

    # ---------------------------------------------------------- construction
    @classmethod
    def for_trainer(cls, trainer, **kw) -> "ServingEngine":
        """Engine sharing a trainer's adapter/scheduler/num_steps/mesh —
        the object to pass to ``trainer.attach_engine``.  ``max_batch``
        caps the rollout chunk size (memory bound); batches larger than it
        run in capacity-sized slices."""
        return cls(trainer.adapter, trainer.scheduler,
                   num_steps=trainer.flow.num_steps, mesh=trainer.mesh, **kw)

    # -------------------------------------------------------------- encoding
    def encode(self, prompts: Sequence[str]) -> np.ndarray:
        """(N, Lc, D) condition embeddings (host-side), LRU-cached per
        prompt; misses are encoded in ONE ConditionProvider batch."""
        if self.provider is None:
            raise ValueError(
                "this engine has no ConditionProvider — submit cond "
                "embeddings directly or construct with provider=...")
        out: Dict[int, np.ndarray] = {}
        miss_rows: Dict[str, List[int]] = {}     # unique prompt -> indices
        for i, p in enumerate(prompts):
            if p in miss_rows:                   # in-batch duplicate: skips
                miss_rows[p].append(i)           # the provider => a hit
                self.cond_cache.hits += 1
                continue
            cached = self.cond_cache.get(p)
            if cached is None:
                miss_rows[p] = [i]
            else:
                out[i] = cached
        if miss_rows:
            fresh = np.asarray(
                self.provider.get(list(miss_rows))["cond"])
            for j, (p, rows) in enumerate(miss_rows.items()):
                # .copy(): a cached row must not be a view pinning the
                # whole miss-batch array in memory past LRU eviction
                self.cond_cache.put(p, fresh[j].copy())
                for i in rows:
                    out[i] = fresh[j]
        return np.stack([out[i] for i in range(len(prompts))])

    # ----------------------------------------------------------------- queue
    def submit(self, cond=None, *, prompt: Optional[str] = None,
               key: Optional[jax.Array] = None, seed: Optional[int] = None,
               num_steps: Optional[int] = None) -> Request:
        """Enqueue one request; returns its handle.  The request's latent is
        fully determined by (cond, key, num_steps) — the same key always
        yields the same latent, whatever batch it lands in."""
        if (cond is None) == (prompt is None):
            raise ValueError("submit exactly one of cond= or prompt=")
        if cond is None:
            cond = self.encode([prompt])[0]
        cond = np.asarray(cond)
        if cond.ndim != 2:
            raise ValueError(
                f"request cond must be (Lc, cond_dim), got {cond.shape}")
        if key is None:
            key = jax.random.PRNGKey(
                seed if seed is not None else self._next_rid)
        key = np.asarray(key)
        steps = self._resolve_steps(num_steps)
        req = Request(self._next_rid, cond, key, steps, self.clock())
        self._next_rid += 1
        self.counters["requests"] += 1
        q = self._queues.setdefault(steps, deque())
        q.append(req)
        # continuous batching: a full bucket never waits for the deadline
        while len(q) >= self.grid.capacity:
            self._dispatch([q.popleft() for _ in range(self.grid.capacity)])
        return req

    def poll(self) -> int:
        """Flush every partial batch whose oldest request has crossed the
        deadline.  Returns the number of requests dispatched."""
        now = self.clock()
        n = 0
        for q in self._queues.values():
            while q and (now - q[0].arrival) >= self.deadline_s:
                take = min(len(q), self.grid.capacity)
                self._dispatch([q.popleft() for _ in range(take)])
                n += take
        return n

    def drain(self) -> int:
        """Dispatch everything still queued, deadline or not."""
        n = 0
        for q in self._queues.values():
            while q:
                take = min(len(q), self.grid.capacity)
                self._dispatch([q.popleft() for _ in range(take)])
                n += take
        return n

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    # ------------------------------------------------------------- execution
    def _resolve_steps(self, num_steps: Optional[int]) -> int:
        if num_steps is None:
            return self.num_steps
        if num_steps < 1:
            raise ValueError(f"num_steps must be >= 1, got {num_steps}")
        return num_steps

    def _account(self, bucket: int, num_steps: int, n_real: int,
                 x0_only: bool) -> None:
        """Single home of the dispatch bookkeeping (queue + rollout paths):
        compile-cache tracking and the dispatch/padding counters.  Trace
        shapes are keyed by (bucket, steps, x0_only) because the two
        executor variants compile separately — warmup covers the queue
        (x0_only) variant, so a trainer-path rollout at the same (bucket,
        steps) is still, correctly, a cold compile."""
        self._note_trace((bucket, num_steps, x0_only))
        d = self.counters["dispatches"]
        d[(bucket, num_steps)] = d.get((bucket, num_steps), 0) + 1
        self.counters["padded_lanes"] += bucket - n_real

    def _note_trace(self, shape, during_warmup: bool = False) -> None:
        if shape in self._traced:
            return
        self._traced.add(shape)
        self.counters["compiles"] += 1
        if not during_warmup and shape not in self._warmed:
            self.counters["cold_dispatches"] += 1

    def _fn(self, num_steps: int, x0_only: bool = False) -> Callable:
        fn = self._fns.get((num_steps, x0_only))
        if fn is None:
            fn = distributed.make_rollout_keyed_sharded(
                self.adapter, self.scheduler, num_steps, self.mesh,
                x0_only=x0_only)
            self._fns[(num_steps, x0_only)] = fn
        return fn

    def _mask(self, num_steps: int) -> jax.Array:
        mask = self._masks.get(num_steps)
        if mask is None:
            mask = self._masks[num_steps] = jnp.ones((num_steps,), bool)
        return mask

    def _execute(self, cond, keys, num_steps: int) -> jax.Array:
        """Run one bucket-shaped batch -> (bucket, Lt, ld) latents
        (accounting is the caller's job)."""
        return self._fn(num_steps, x0_only=True)(
            self.params, cond, keys, self._mask(num_steps))

    def _pad(self, arr: jax.Array, bucket: int) -> jax.Array:
        pad = bucket - arr.shape[0]
        if not pad:
            return arr
        xp = np if isinstance(arr, np.ndarray) else jnp
        return xp.concatenate(
            [arr, xp.zeros((pad,) + arr.shape[1:], arr.dtype)])

    def _dispatch(self, batch: List[Request]) -> None:
        if self.params is None:
            raise RuntimeError(
                "engine has no params — pass params= at construction for "
                "the queue path (or use engine.rollout for trainers)")
        steps = batch[0].num_steps
        bucket = self.grid.pick(len(batch))
        self._account(bucket, steps, len(batch), x0_only=True)
        cond = self._pad(np.stack([r.cond for r in batch]), bucket)
        keys = self._pad(np.stack([r.key for r in batch]), bucket)
        holder = _BatchResult(self._execute(cond, keys, steps))
        for i, r in enumerate(batch):
            r._result = (holder, i)

    # ----------------------------------------------------------- conveniences
    def serve(self, requests: Union[Sequence[str], jax.Array],
              key: Optional[jax.Array] = None,
              num_steps: Optional[int] = None) -> jax.Array:
        """Synchronous batch serve: prompts (via the cond cache) or a
        (N, Lc, D) cond array -> (N, Lt, ld) latents.  Request i's key is
        ``fold_in(key, i)`` — per-request results are independent of N,
        bucket layout, and max_batch."""
        if key is None:
            key = jax.random.PRNGKey(0)
        if len(requests) and isinstance(requests[0], str):
            cond = self.encode(list(requests))
        else:
            cond = np.asarray(requests)
        keys = np.asarray(request_keys(key, cond.shape[0]))
        handles = [self.submit(cond=cond[i], key=keys[i],
                               num_steps=num_steps)
                   for i in range(cond.shape[0])]
        self.drain()
        return jnp.asarray(np.stack([h.result() for h in handles]))

    def rollout(self, params, cond: jax.Array, key: jax.Array,
                sde_mask: Optional[jax.Array] = None,
                num_steps: Optional[int] = None) -> Trajectory:
        """Trainer-facing batched rollout through the engine's compile
        cache: per-request keys (fold_in(key, i)), capacity-sized chunks,
        bucket padding in, exact-size Trajectory out."""
        steps = self._resolve_steps(num_steps)
        if sde_mask is None:
            sde_mask = jnp.ones((steps,), bool)
        B = cond.shape[0]
        keys = request_keys(key, B)
        cap = self.grid.capacity
        chunks: List[Trajectory] = []
        for i in range(0, B, cap):
            c, k = cond[i:i + cap], keys[i:i + cap]
            n = c.shape[0]
            bucket = self.grid.pick(n)
            self._account(bucket, steps, n, x0_only=False)
            traj = self._fn(steps)(params, self._pad(c, bucket),
                                   self._pad(k, bucket), sde_mask)
            chunks.append(Trajectory(
                xs=traj.xs[:, :n], logps=traj.logps[:, :n], ts=traj.ts,
                sde_mask=traj.sde_mask, cond=traj.cond[:n]))
        if len(chunks) == 1:
            return chunks[0]
        return Trajectory(
            xs=jnp.concatenate([t.xs for t in chunks], axis=1),
            logps=jnp.concatenate([t.logps for t in chunks], axis=1),
            ts=chunks[0].ts, sde_mask=chunks[0].sde_mask,
            cond=jnp.concatenate([t.cond for t in chunks], axis=0))

    # ---------------------------------------------------------------- warmup
    def warmup(self, num_steps_tiers: Optional[Sequence[int]] = None,
               params=None) -> Dict[str, float]:
        """Pre-trace the full (bucket × num_steps) grid so steady-state
        serving never compiles.  Returns per-shape trace+first-run seconds;
        the total also lands in ``counters['warmup_s']``."""
        params = params if params is not None else self.params
        if params is None:
            raise RuntimeError("warmup needs params")
        tiers = sorted(set(num_steps_tiers or [self.num_steps]))
        report: Dict[str, float] = {}
        for steps in tiers:
            for bucket in self.grid.sizes:
                cond = np.zeros((bucket, self.cond_len,
                                 self.adapter.cond_dim), np.float32)
                keys = np.zeros((bucket, 2), np.uint32)
                t0 = time.perf_counter()
                x0 = self._fn(steps, x0_only=True)(params, cond, keys,
                                                   self._mask(steps))
                jax.block_until_ready(x0)
                dt = time.perf_counter() - t0
                report[f"b{bucket}/s{steps}"] = dt
                self._warmed.add((bucket, steps, True))
                self._note_trace((bucket, steps, True), during_warmup=True)
        self.counters["warmup_s"] += sum(report.values())
        return report

    # ----------------------------------------------------------------- stats
    @property
    def stats(self) -> Dict[str, Any]:
        c = self.counters
        return {
            "requests": c["requests"],
            "pending": self.pending(),
            "dispatches": dict(c["dispatches"]),
            "padded_lanes": c["padded_lanes"],
            "compiled_shapes": sorted(self._traced),
            "warmed_shapes": sorted(self._warmed),
            "compiles": c["compiles"],
            "cold_dispatches": c["cold_dispatches"],
            "warmup_s": c["warmup_s"],
            "cond_cache": {"hits": self.cond_cache.hits,
                           "misses": self.cond_cache.misses,
                           "entries": len(self.cond_cache)},
            "buckets": self.grid.sizes,
            "data_parallel": (1 if self.mesh is None
                              else self.mesh.shape[distributed.DATA_AXIS]),
        }
