"""Shape-bucket policy for the serving engine.

jit recompiles are the tax on dynamic batching: every distinct
(batch, num_steps) shape traces and compiles a fresh executable.  The
engine therefore admits requests into a small fixed grid of batch tiers
(default: powers of two up to ``max_batch``), pads partial batches up to
the smallest covering tier, and pre-traces the whole grid at startup — so
steady-state serving never compiles.

When inference is sharded over a data mesh every tier is rounded up to a
multiple of the device count (``dp_align``): shard_map needs equal per-
device slices, and padded lanes are free under the per-request-keyed
rollout (real lanes are bit-identical regardless of who pads the batch).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple


def default_buckets(max_batch: int) -> Tuple[int, ...]:
    """Powers of two up to and including ``max_batch`` (the tier ladder a
    mixed request load actually exercises: full buckets ride the top tier,
    deadline-flushed remainders the small ones)."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    tiers = []
    b = 1
    while b < max_batch:
        tiers.append(b)
        b *= 2
    tiers.append(max_batch)
    return tuple(tiers)


class BucketGrid:
    """The (batch,) tier ladder, optionally dp-aligned.

    ``pick(n)`` returns the smallest tier >= n; callers never dispatch more
    than ``capacity`` (= the largest tier) requests per batch.
    """

    def __init__(self, buckets: Optional[Sequence[int]] = None, *,
                 max_batch: int = 8, dp: int = 1):
        if dp < 1:
            raise ValueError(f"dp must be >= 1, got {dp}")
        if buckets:
            raw = tuple(buckets)
            over = [b for b in raw if b > max_batch]
            if over:
                raise ValueError(
                    f"bucket sizes {over} exceed max_batch={max_batch} "
                    "(the memory cap) — raise max_batch or shrink the "
                    "tiers")
        else:
            raw = default_buckets(max_batch)
        if any(b < 1 for b in raw):
            raise ValueError(f"bucket sizes must be >= 1, got {raw}")
        # dp-align each tier, then dedupe (1 and 2 both round to 4 on dp=4)
        aligned = sorted({-(-b // dp) * dp for b in raw})
        # alignment must not raise the max_batch memory cap: clamp the
        # ladder to the largest dp multiple <= max_batch (dp itself when
        # the cap is below one per-device lane each — the smallest batch
        # a mesh can serve at all)
        cap = max(dp, (max_batch // dp) * dp)
        self.dp = dp
        self.sizes: Tuple[int, ...] = (tuple(b for b in aligned if b <= cap)
                                       or (cap,))

    @property
    def capacity(self) -> int:
        return self.sizes[-1]

    def pick(self, n: int) -> int:
        """Smallest tier covering ``n`` requests (n <= capacity)."""
        if n < 1:
            raise ValueError(f"cannot bucket {n} requests")
        for b in self.sizes:
            if b >= n:
                return b
        raise ValueError(
            f"{n} requests exceed the largest bucket ({self.capacity}); "
            "dispatch in capacity-sized slices")

    def __repr__(self) -> str:
        return f"BucketGrid(sizes={self.sizes}, dp={self.dp})"


class StepGrid:
    """The admitted ``num_steps`` quality tiers — the second axis of the
    compile grid.

    The batch ladder bounds one shape axis; this bounds the other: a
    request may only ask for a ``num_steps`` value in the tier grid
    (e.g. a cheap 4-step draft tier next to the full-quality tier), and
    ``warmup()`` pre-traces every (bucket × step tier) pair.  Together
    they make "steady state never compiles" *provable*: every admitted
    request lands on a warmed shape, instead of one odd ``num_steps=7``
    submit silently compiling a fresh executable on the hot path.
    """

    def __init__(self, tiers: Optional[Sequence[int]] = None, *,
                 default: int):
        if default < 1:
            raise ValueError(f"num_steps must be >= 1, got {default}")
        raw = tuple(tiers) if tiers else ()
        if any(s < 1 for s in raw):
            raise ValueError(f"step tiers must be >= 1, got {raw}")
        self.default = default
        self.sizes: Tuple[int, ...] = tuple(sorted(set(raw) | {default}))

    def resolve(self, num_steps: Optional[int]) -> int:
        """Default tier for ``None``; otherwise admit only grid members —
        an off-grid value would compile on the hot path."""
        if num_steps is None:
            return self.default
        if num_steps not in self.sizes:
            raise ValueError(
                f"num_steps={num_steps} is outside the warmed step-tier "
                f"grid {self.sizes} — off-grid values would compile on "
                "the hot path; pass step_tiers= at engine construction "
                "to widen the grid")
        return num_steps

    def __repr__(self) -> str:
        return f"StepGrid(sizes={self.sizes}, default={self.default})"
