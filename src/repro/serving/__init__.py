"""``repro.serving`` — the production inference subsystem.

A request-queue engine with bucketed continuous batching (bounded jit
recompiles + deadline flush), compile-cache warmup, an LRU cond-encoding
cache, and sharded inference over ``repro.distributed``'s "data" mesh —
bit-identical per request across bucket layouts, batch mates, and device
counts (the per-request-keyed rollout invariant).

``FlowSampler`` (repro.api.serving) and ``launch/serve.py`` are thin
clients; trainers opt in via ``BaseTrainer.attach_engine``.
"""
from repro.serving.buckets import BucketGrid, default_buckets
from repro.serving.engine import CondCache, Request, ServingEngine

__all__ = ["BucketGrid", "default_buckets", "CondCache", "Request",
           "ServingEngine"]
