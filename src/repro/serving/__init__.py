"""``repro.serving`` — the production inference subsystem.

A multi-tenant request-queue engine with bucketed continuous batching
(bounded jit recompiles on both the batch and num_steps axes + deadline
flush), priority classes with weighted-fair dequeue across tenants,
per-request SLO deadlines, admission control with structured
retry-after backpressure, compile-cache warmup, an LRU cond-encoding
cache, and sharded inference over ``repro.distributed``'s "data" mesh —
bit-identical per request across bucket layouts, batch mates, scheduling
order, and device counts (the per-request-keyed rollout invariant).

``FlowSampler`` (repro.api.serving) and ``launch/serve.py`` are thin
clients; trainers opt in via ``BaseTrainer.attach_engine``.
"""
from repro.serving.admission import (DEFAULT_CLASSES, AdmissionConfig,
                                     AdmissionController, PriorityClass,
                                     RetryAfter)
from repro.serving.buckets import BucketGrid, StepGrid, default_buckets
from repro.serving.engine import CondCache, Request, ServingEngine

__all__ = ["AdmissionConfig", "AdmissionController", "BucketGrid",
           "CondCache", "DEFAULT_CLASSES", "PriorityClass", "Request",
           "RetryAfter", "ServingEngine", "StepGrid", "default_buckets"]
