"""Admission control, priority classes, and weighted-fair multi-tenant
scheduling for the serving engine.

The engine's queue is the contention point between tenants under heavy
load, so its policy lives here, separate from the dispatch mechanics:

* **Priority classes** (:class:`PriorityClass`) — named tiers with a
  weighted-fair share (``weight``), a bounded queue depth (``max_depth``)
  and an optional default SLO (``slo_s``).  The defaults model the usual
  product split: ``interactive`` (small bounded queue, big share),
  ``standard``, and ``batch`` (deep queue, small share).
* **Admission control / backpressure** — :meth:`AdmissionController.admit`
  rejects a submit once its class is at ``max_depth`` by raising
  :class:`RetryAfter`, a *structured* error carrying a machine-readable
  payload (class, tenant, depth, limit, ``retry_after_s``) instead of
  queueing unboundedly.  ``retry_after_s`` is derived from the earliest
  dispatch deadline still queued in the class — the soonest a flush can
  free a slot — so clients back off a meaningful amount, deterministically
  under an injected clock.
* **Weighted-fair dequeue** — batches are filled by stride scheduling over
  the per-(class, tenant) FIFO queues: each queue holds a monotonically
  advancing ``pass`` value and the scheduler always serves the lowest one,
  advancing it by ``1 / (class_weight * tenant_weight)``.  Heavier queues
  therefore get proportionally more batch slots, and *every* backlogged
  queue's pass eventually becomes the minimum — no starvation, with a
  deterministic total order (ties break on class rank, then tenant name).
* **Deadline supremacy** — :meth:`take` serves queues holding a request
  whose dispatch deadline (the batching flush deadline or the request's
  SLO deadline, whichever is sooner) has expired *before* fairness
  applies: a deadline is a promise, fairness is a policy.

Everything here is pure host-side bookkeeping driven by the caller's
clock — no wall-clock reads, no randomness — which is what makes the
seeded fuzz harness in ``tests/test_serving.py`` deterministic.
"""
from __future__ import annotations

import math
from collections import OrderedDict, deque
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Tuple

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.serving.engine import Request


@dataclass(frozen=True)
class PriorityClass:
    """One priority tier: fair-share weight, bounded queue depth, and an
    optional default completion SLO applied to requests that don't carry
    their own."""

    name: str
    weight: int = 1
    max_depth: int = 64
    slo_s: Optional[float] = None

    def __post_init__(self):
        if not self.name:
            raise ValueError("priority class needs a name")
        if self.weight < 1:
            raise ValueError(
                f"priority class {self.name!r}: weight must be >= 1, "
                f"got {self.weight}")
        if self.max_depth < 1:
            raise ValueError(
                f"priority class {self.name!r}: max_depth must be >= 1, "
                f"got {self.max_depth}")
        if self.slo_s is not None and self.slo_s <= 0:
            raise ValueError(
                f"priority class {self.name!r}: slo_s must be > 0, "
                f"got {self.slo_s}")


DEFAULT_CLASSES: Tuple[PriorityClass, ...] = (
    PriorityClass("interactive", weight=4, max_depth=32),
    PriorityClass("standard", weight=2, max_depth=64),
    PriorityClass("batch", weight=1, max_depth=256),
)


@dataclass(frozen=True)
class AdmissionConfig:
    """Queue policy for a :class:`~repro.serving.ServingEngine`.

    ``tenant_weights`` is a tuple of (tenant, weight) pairs (tuple, not
    dict, so the config stays hashable/frozen); unlisted tenants weigh 1.
    """

    classes: Tuple[PriorityClass, ...] = DEFAULT_CLASSES
    tenant_weights: Tuple[Tuple[str, int], ...] = ()
    default_class: str = "standard"

    def __post_init__(self):
        names = [c.name for c in self.classes]
        if not names:
            raise ValueError("AdmissionConfig needs at least one class")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate priority class names: {names}")
        if self.default_class not in names:
            raise ValueError(
                f"default_class {self.default_class!r} is not one of "
                f"{names}")
        for tenant, w in self.tenant_weights:
            if w < 1:
                raise ValueError(
                    f"tenant {tenant!r}: weight must be >= 1, got {w}")

    def tenant_weight(self, tenant: str) -> int:
        return dict(self.tenant_weights).get(tenant, 1)


class RetryAfter(RuntimeError):
    """Structured admission rejection: the priority class's queue is at its
    bound.  Carries a JSON-ready payload so API layers can forward it
    verbatim (HTTP 429 + Retry-After semantics)."""

    def __init__(self, *, priority: str, tenant: str, depth: int,
                 limit: int, retry_after_s: float):
        self.priority = priority
        self.tenant = tenant
        self.depth = depth
        self.limit = limit
        self.retry_after_s = retry_after_s
        super().__init__(
            f"queue for priority class {priority!r} is full "
            f"({depth}/{limit} queued, tenant {tenant!r}) — retry in "
            f"{retry_after_s:.3f}s")

    def to_json(self) -> Dict[str, Any]:
        return {"error": "over_capacity", "priority": self.priority,
                "tenant": self.tenant, "depth": self.depth,
                "limit": self.limit,
                "retry_after_s": round(self.retry_after_s, 6)}


class AdmissionController:
    """Bounded, weighted-fair, deadline-aware request queues.

    One FIFO deque per (num_steps tier, class, tenant); stride-scheduling
    state (``_pass``) persists across dispatches so fair shares hold over
    the run, not per batch.  All methods take ``now`` from the caller —
    the controller never reads a clock.
    """

    def __init__(self, config: Optional[AdmissionConfig] = None):
        self.config = config or AdmissionConfig()
        self._classes: Dict[str, PriorityClass] = {
            c.name: c for c in self.config.classes}
        self._rank: Dict[str, int] = {
            c.name: i for i, c in enumerate(self.config.classes)}
        # per steps tier: (class, tenant) -> FIFO of Requests
        self._q: Dict[int, "OrderedDict[Tuple[str, str], deque]"] = {}
        self._pass: Dict[Tuple[str, str], float] = {}
        self._vtime = 0.0
        self.depths: Dict[str, int] = {c: 0 for c in self._classes}
        self.admitted: Dict[str, int] = {c: 0 for c in self._classes}
        self.rejected: Dict[str, int] = {c: 0 for c in self._classes}

    # ------------------------------------------------------------- classes
    def resolve_class(self, name: Optional[str]) -> PriorityClass:
        if name is None:
            name = self.config.default_class
        cls = self._classes.get(name)
        if cls is None:
            raise ValueError(
                f"unknown priority class {name!r} — configured classes: "
                f"{sorted(self._classes)}")
        return cls

    # ----------------------------------------------------------- admission
    def admit(self, req: "Request", now: float) -> None:
        """Enqueue ``req`` or raise :class:`RetryAfter` if its class is at
        its depth bound."""
        cls = self._classes[req.priority]
        depth = self.depths[req.priority]
        if depth >= cls.max_depth:
            self.rejected[req.priority] += 1
            raise RetryAfter(
                priority=req.priority, tenant=req.tenant, depth=depth,
                limit=cls.max_depth,
                retry_after_s=self._retry_after(req.priority, now))
        tier = self._q.setdefault(req.num_steps, OrderedDict())
        tier.setdefault((req.priority, req.tenant), deque()).append(req)
        self.depths[req.priority] += 1
        self.admitted[req.priority] += 1

    def _retry_after(self, priority: str, now: float) -> float:
        """Soonest a queue slot can free: the earliest dispatch deadline
        still queued in the class (a poll() then flushes it)."""
        soonest = math.inf
        for tier in self._q.values():
            for (cls, _), q in tier.items():
                if cls != priority:
                    continue
                for r in q:
                    soonest = min(soonest, r.deadline)
        if not math.isfinite(soonest):
            return 0.0
        return max(soonest - now, 0.0)

    # ------------------------------------------------------------ queries
    def tiers(self) -> List[int]:
        return [s for s, tier in self._q.items()
                if any(q for q in tier.values())]

    def ready(self, steps: int) -> int:
        tier = self._q.get(steps)
        if not tier:
            return 0
        return sum(len(q) for q in tier.values())

    def pending(self) -> int:
        return sum(self.depths.values())

    def has_expired(self, steps: int, now: float) -> bool:
        """Any queued request in the tier past its dispatch deadline?"""
        tier = self._q.get(steps)
        if not tier:
            return False
        return any(r.deadline <= now for q in tier.values() for r in q)

    def oldest_deadline(self, steps: int) -> float:
        tier = self._q.get(steps)
        if not tier:
            return math.inf
        return min((r.deadline for q in tier.values() for r in q),
                   default=math.inf)

    # ----------------------------------------------------------- dequeue
    def _queue_key(self, qk: Tuple[str, str]):
        """Deterministic stride order: lowest pass wins; ties break on
        class rank (config order = priority order), then tenant name."""
        return (self._pass.get(qk, self._vtime), self._rank[qk[0]], qk[1])

    def _charge(self, qk: Tuple[str, str]) -> None:
        cls, tenant = qk
        cur = max(self._pass.get(qk, self._vtime), self._vtime)
        stride = 1.0 / (self._classes[cls].weight
                        * self.config.tenant_weight(tenant))
        self._pass[qk] = cur + stride
        self._vtime = cur

    def _pop(self, tier, qk: Tuple[str, str]) -> "Request":
        req = tier[qk].popleft()
        self.depths[qk[0]] -= 1
        self._charge(qk)
        return req

    def take(self, steps: int, k: int, now: float) -> List["Request"]:
        """Dequeue up to ``k`` requests of the ``steps`` tier: queues
        holding an expired-deadline request flush first (front-of-queue
        FIFO order), then the remaining slots fill weighted-fair."""
        tier = self._q.get(steps)
        out: List["Request"] = []
        if not tier:
            return out
        # phase 1 — deadline supremacy: the queue whose earliest queued
        # deadline has expired is served before any fairness accounting
        while len(out) < k:
            best, best_key = None, None
            for qk, q in tier.items():
                d = min((r.deadline for r in q), default=math.inf)
                if d > now:
                    continue
                cand = (d, self._rank[qk[0]], qk[1])
                if best_key is None or cand < best_key:
                    best, best_key = qk, cand
            if best is None:
                break
            out.append(self._pop(tier, best))
        # phase 2 — weighted-fair fill from whatever is still queued
        while len(out) < k:
            nonempty = [qk for qk, q in tier.items() if q]
            if not nonempty:
                break
            out.append(self._pop(tier, min(nonempty, key=self._queue_key)))
        return out

    # -------------------------------------------------------------- stats
    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready per-class view for the engine's stats/health."""
        return {
            c.name: {"depth": self.depths[c.name], "limit": c.max_depth,
                     "weight": c.weight, "slo_s": c.slo_s,
                     "admitted": self.admitted[c.name],
                     "rejected": self.rejected[c.name]}
            for c in self.config.classes}
