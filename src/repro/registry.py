"""Global component registry — the paper's §2.1 contribution.

Flow-Factory decouples Models (adapters), Trainers (algorithms), Rewards and
Schedulers behind a single plug-and-play registry.  Components register
themselves under a (kind, name) key; anything registered can be instantiated
from configuration alone, so any (model × algorithm × reward × scheduler)
combination is reachable without code changes — O(M+N) integration cost.

Usage::

    @register("trainer", "flow_grpo")
    class FlowGRPOTrainer(BaseTrainer): ...

    trainer_cls = lookup("trainer", cfg.trainer_type)
    trainer = build("trainer", cfg.trainer_type, model=model, **cfg.trainer_args)
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Dict, Iterable, Mapping, Tuple, Union

# kind -> name -> class/factory
_REGISTRY: Dict[str, Dict[str, Any]] = {}

KINDS = ("adapter", "trainer", "reward", "scheduler", "arch", "frontend",
         "aggregator", "optimizer", "dataset")


class RegistryError(KeyError):
    pass


def register(kind: str, name: str, *, override: bool = False) -> Callable:
    """Class decorator registering ``cls`` under ``(kind, name)``."""
    if kind not in KINDS:
        raise RegistryError(f"unknown registry kind {kind!r}; kinds={KINDS}")

    def deco(obj: Any) -> Any:
        bucket = _REGISTRY.setdefault(kind, {})
        if name in bucket and not override and bucket[name] is not obj:
            raise RegistryError(f"{kind}:{name} already registered")
        bucket[name] = obj
        # attach identity so components can introspect their registry key
        try:
            obj.registry_kind = kind
            obj.registry_name = name
        except (AttributeError, TypeError):  # e.g. functools.partial
            pass
        return obj

    return deco


_AUTOLOADED = False


def _autoload() -> None:
    """Import every registering module (lazy — keeps `import repro` free of
    jax initialization so XLA_FLAGS can still be set by launchers)."""
    global _AUTOLOADED
    if _AUTOLOADED:
        return
    _AUTOLOADED = True
    import importlib
    for mod in ("repro.core.schedulers", "repro.core.trainers",
                "repro.core.rewards", "repro.models.flow",
                "repro.models.frontends", "repro.configs",
                "repro.data.prompts", "repro.optim"):
        importlib.import_module(mod)


def lookup(kind: str, name: str) -> Any:
    if name not in _REGISTRY.get(kind, {}):
        _autoload()
    try:
        return _REGISTRY[kind][name]
    except KeyError:
        avail = sorted(_REGISTRY.get(kind, {}))
        raise RegistryError(
            f"no {kind!r} named {name!r}; available: {avail}") from None


def build(kind: str, name: str, *args: Any, **kwargs: Any) -> Any:
    """Instantiate a registered component."""
    return lookup(kind, name)(*args, **kwargs)


def names(kind: str) -> Tuple[str, ...]:
    _autoload()
    return tuple(sorted(_REGISTRY.get(kind, {})))


def items(kind: str) -> Iterable[Tuple[str, Any]]:
    _autoload()
    return sorted(_REGISTRY.get(kind, {}).items())


def is_registered(kind: str, name: str) -> bool:
    return name in _REGISTRY.get(kind, {})


# ---------------------------------------------------------------------------
# Config-driven construction + introspection (the Experiment front door)
# ---------------------------------------------------------------------------

#: a component spec: either a bare registry name or a nested dict
#:   {"type": <name>, "args": {<kwarg>: <value-or-nested-spec>, ...}}
#: nested specs inside ``args`` additionally carry a "kind" key so the
#: registry knows which bucket to resolve them from.
Spec = Union[str, Mapping[str, Any]]


def _normalize_spec(kind: str, spec: Spec) -> Tuple[str, Dict[str, Any]]:
    if isinstance(spec, str):
        return spec, {}
    if isinstance(spec, Mapping):
        extra = set(spec) - {"type", "name", "args", "kind"}
        if extra:
            raise RegistryError(
                f"bad {kind} spec: unknown key(s) {sorted(extra)}; a spec is "
                "a name or {'type': <name>, 'args': {...}}")
        name = spec.get("type") or spec.get("name")
        if not isinstance(name, str):
            raise RegistryError(f"bad {kind} spec {spec!r}: missing 'type'")
        args = spec.get("args", {})
        if not isinstance(args, Mapping):
            raise RegistryError(f"bad {kind} spec {name!r}: 'args' must be a "
                                f"dict, got {type(args).__name__}")
        return name, dict(args)
    raise RegistryError(f"bad {kind} spec {spec!r}: expected a registry name "
                        "or a {'type': ..., 'args': {...}} dict")


def _is_nested_spec(v: Any) -> bool:
    return isinstance(v, Mapping) and "kind" in v and ("type" in v
                                                       or "name" in v)


def _validate_call(kind: str, name: str, obj: Any, args: Tuple,
                   kwargs: Dict[str, Any]) -> None:
    try:
        sig = inspect.signature(obj)
    except (TypeError, ValueError):      # builtins / C callables: skip
        return
    try:
        sig.bind(*args, **kwargs)
    except TypeError as e:
        accepted = [p.name for p in sig.parameters.values()
                    if p.kind not in (p.VAR_POSITIONAL, p.VAR_KEYWORD)]
        raise RegistryError(
            f"invalid arguments for {kind}:{name}: {e}; accepted "
            f"parameters: {accepted}") from None


def build_from_config(kind: str, spec: Spec, *args: Any, **extra: Any) -> Any:
    """Instantiate a component from a declarative spec.

    ``spec`` is a registry name or ``{"type": name, "args": {...}}``; arg
    values that are themselves ``{"kind": ..., "type": ..., "args": ...}``
    dicts are built recursively.  Arguments are validated against the
    component signature so a typo fails with the accepted parameter list
    instead of a deep ``TypeError``."""
    name, kwargs = _normalize_spec(kind, spec)
    kwargs = {k: (build_from_config(v["kind"], v) if _is_nested_spec(v)
                  else v) for k, v in kwargs.items()}
    overlap = sorted(set(kwargs) & set(extra))
    if overlap:
        raise RegistryError(
            f"{kind}:{name}: argument(s) {overlap} given both in the spec "
            "and by the caller")
    kwargs.update(extra)
    obj = lookup(kind, name)
    _validate_call(kind, name, obj, args, kwargs)
    return obj(*args, **kwargs)


def describe(kind: str, name: str = None) -> Dict[str, Any]:
    """Introspection helper: constructor signature + one-line doc for one
    registered component (or, with ``name=None``, for every one of ``kind``)."""
    if name is None:
        return {n: describe(kind, n) for n in names(kind)}
    obj = lookup(kind, name)
    doc = (inspect.getdoc(obj) or "").split("\n", 1)[0]
    params: Dict[str, Any] = {}
    try:
        sig = inspect.signature(obj)
    except (TypeError, ValueError):
        sig = None
    if sig is not None:
        for p in sig.parameters.values():
            if p.kind in (p.VAR_POSITIONAL, p.VAR_KEYWORD):
                continue
            params[p.name] = {
                "default": (None if p.default is p.empty
                            else repr(p.default)),
                "required": p.default is p.empty,
                "annotation": (None if p.annotation is p.empty
                               else str(p.annotation)),
            }
    return {"kind": kind, "name": name, "doc": doc, "params": params}
