"""Global component registry — the paper's §2.1 contribution.

Flow-Factory decouples Models (adapters), Trainers (algorithms), Rewards and
Schedulers behind a single plug-and-play registry.  Components register
themselves under a (kind, name) key; anything registered can be instantiated
from configuration alone, so any (model × algorithm × reward × scheduler)
combination is reachable without code changes — O(M+N) integration cost.

Usage::

    @register("trainer", "flow_grpo")
    class FlowGRPOTrainer(BaseTrainer): ...

    trainer_cls = lookup("trainer", cfg.trainer_type)
    trainer = build("trainer", cfg.trainer_type, model=model, **cfg.trainer_args)
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterable, Tuple

# kind -> name -> class/factory
_REGISTRY: Dict[str, Dict[str, Any]] = {}

KINDS = ("adapter", "trainer", "reward", "scheduler", "arch", "frontend",
         "aggregator", "optimizer", "dataset")


class RegistryError(KeyError):
    pass


def register(kind: str, name: str, *, override: bool = False) -> Callable:
    """Class decorator registering ``cls`` under ``(kind, name)``."""
    if kind not in KINDS:
        raise RegistryError(f"unknown registry kind {kind!r}; kinds={KINDS}")

    def deco(obj: Any) -> Any:
        bucket = _REGISTRY.setdefault(kind, {})
        if name in bucket and not override and bucket[name] is not obj:
            raise RegistryError(f"{kind}:{name} already registered")
        bucket[name] = obj
        # attach identity so components can introspect their registry key
        try:
            obj.registry_kind = kind
            obj.registry_name = name
        except (AttributeError, TypeError):  # e.g. functools.partial
            pass
        return obj

    return deco


_AUTOLOADED = False


def _autoload() -> None:
    """Import every registering module (lazy — keeps `import repro` free of
    jax initialization so XLA_FLAGS can still be set by launchers)."""
    global _AUTOLOADED
    if _AUTOLOADED:
        return
    _AUTOLOADED = True
    import importlib
    for mod in ("repro.core.schedulers", "repro.core.trainers",
                "repro.core.rewards", "repro.models.flow",
                "repro.models.frontends"):
        importlib.import_module(mod)


def lookup(kind: str, name: str) -> Any:
    if name not in _REGISTRY.get(kind, {}):
        _autoload()
    try:
        return _REGISTRY[kind][name]
    except KeyError:
        avail = sorted(_REGISTRY.get(kind, {}))
        raise RegistryError(
            f"no {kind!r} named {name!r}; available: {avail}") from None


def build(kind: str, name: str, *args: Any, **kwargs: Any) -> Any:
    """Instantiate a registered component."""
    return lookup(kind, name)(*args, **kwargs)


def names(kind: str) -> Tuple[str, ...]:
    _autoload()
    return tuple(sorted(_REGISTRY.get(kind, {})))


def items(kind: str) -> Iterable[Tuple[str, Any]]:
    _autoload()
    return sorted(_REGISTRY.get(kind, {}).items())


def is_registered(kind: str, name: str) -> bool:
    return name in _REGISTRY.get(kind, {})
