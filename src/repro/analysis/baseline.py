"""Baseline file: accepted pre-existing findings that don't block CI.

A finding's fingerprint is ``sha1(rule | path | normalized snippet |
occurrence-index)`` — line numbers are deliberately excluded so unrelated
edits above a finding don't invalidate the baseline, while the occurrence
index keeps two identical snippets in one file distinct.

Workflow: ``python -m repro.analysis --update-baseline`` writes the file;
a clean run is "every finding is either suppressed inline (with a reason)
or fingerprint-matched here"; stale entries (baselined but no longer
found) are reported so the file shrinks as debt is paid.
"""
from __future__ import annotations

import hashlib
import json
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

from repro.analysis.core import Finding

DEFAULT_BASELINE = ".jaxlint-baseline.json"


def _normalize(snippet: str) -> str:
    return " ".join(snippet.split())


def fingerprints(findings: Sequence[Finding]) -> List[Tuple[Finding, str]]:
    """Stable per-finding fingerprints (occurrence-indexed)."""
    seen: Dict[Tuple[str, str, str], int] = {}
    out: List[Tuple[Finding, str]] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
        key = (f.rule, f.path, _normalize(f.snippet))
        idx = seen.get(key, 0)
        seen[key] = idx + 1
        digest = hashlib.sha1(
            "|".join([*key, str(idx)]).encode()).hexdigest()[:16]
        out.append((f, digest))
    return out


def load(path: Path) -> Dict[str, Dict]:
    """fingerprint -> entry ({rule, path, snippet})."""
    if not path.exists():
        return {}
    data = json.loads(path.read_text())
    return {e["fingerprint"]: e for e in data.get("findings", [])}


def save(path: Path, findings: Sequence[Finding]) -> int:
    entries = [{"fingerprint": fp, "rule": f.rule, "path": f.path,
                "snippet": _normalize(f.snippet)}
               for f, fp in fingerprints(findings)]
    path.write_text(json.dumps(
        {"comment": "accepted pre-existing jaxlint findings; regenerate "
                    "with `python -m repro.analysis --update-baseline`",
         "findings": entries}, indent=2) + "\n")
    return len(entries)


def split(findings: Sequence[Finding], baseline: Dict[str, Dict]
          ) -> Tuple[List[Finding], List[Finding], List[Dict]]:
    """(new, baselined, stale-entries)."""
    new: List[Finding] = []
    matched: List[Finding] = []
    used: set = set()
    for f, fp in fingerprints(findings):
        if fp in baseline:
            matched.append(f)
            used.add(fp)
        else:
            new.append(f)
    stale = [e for fp, e in baseline.items() if fp not in used]
    return new, matched, stale
