"""The jaxlint rule catalog — each rule encodes a bug class this repo has
actually had (or explicitly guards against).  See the module docstring of
``repro.analysis`` for how to add one.

R000 suppression-without-justification  accepted risk must say why
R001 prng-key-reuse                     same key consumed twice
R002 host-sync-in-hot-loop              the PR-5 per-metric sync class
R003 mutable-closure-capture            the PR-2 NFT frozen-reference class
R004 python-control-flow-on-tracer      if/while on jnp-derived values
R005 donated-buffer-reuse               read-after-donate is a dead buffer
R006 recompile-hazard                   unhashable statics / jit-in-loop
R007 blocking-drain-in-dispatch-loop    sync on the just-dispatched step
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.core import Finding, Module, Rule, register_rule, \
    rule_ids
from repro.analysis.scopes import FuncInfo, ScopeGraph, _donated_positions, \
    _enclosing_class, last_name, root_name, shallow_walk

# namespaces whose calls produce device values / tracers
_DEVICE_ROOTS = {"jnp", "lax", "pl"}
# jax.<first-attr> members that do NOT produce device values
_JAX_HOST = {"device_get", "tree_util", "tree", "debug", "config",
             "devices", "local_devices", "device_count", "make_mesh",
             "local_device_count", "default_backend", "make_jaxpr",
             "eval_shape", "ShapeDtypeStruct", "block_until_ready",
             "profiler", "sharding", "clear_caches", "tree_map",
             "tree_leaves", "tree_structure", "tree_flatten",
             "tree_unflatten"}
# array-method reductions: inside a traced scope, calling one on anything
# yields a tracer whatever the receiver is
_ARRAY_REDUCERS = {"any", "all", "sum", "mean", "max", "min", "prod",
                   "argmax", "argmin"}
# attribute reads that are static at trace time
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}

_SYNC_BUILTINS = {"float", "int", "bool", "complex"}
_SYNC_METHODS = {"item", "tolist"}

_SAMPLERS = {
    "normal", "uniform", "bernoulli", "randint", "bits", "categorical",
    "choice", "permutation", "gumbel", "exponential", "laplace", "logistic",
    "truncated_normal", "beta", "gamma", "dirichlet", "poisson",
    "rademacher", "cauchy", "multivariate_normal", "orthogonal", "ball",
    "loggamma", "maxwell", "split",
}
_KEY_PRODUCERS = {"PRNGKey", "key", "split", "fold_in", "wrap_key_data",
                  "clone"}
_UNHASHABLE_LITERALS = (ast.Dict, ast.List, ast.Set, ast.ListComp,
                        ast.SetComp, ast.DictComp, ast.GeneratorExp)


def _attr_chain(expr: ast.expr) -> List[str]:
    """["jax", "random", "normal"] for ``jax.random.normal``."""
    parts: List[str] = []
    while isinstance(expr, ast.Attribute):
        parts.append(expr.attr)
        expr = expr.value
    if isinstance(expr, ast.Name):
        parts.append(expr.id)
    return list(reversed(parts))


def _is_jax_random(func: ast.expr, name: str) -> bool:
    """Does ``func`` denote ``jax.random.<name>`` (or ``random.<name>``
    via ``from jax import random`` / ``jr.<name>``)?"""
    chain = _attr_chain(func)
    if not chain or chain[-1] != name:
        return False
    if chain[0] in ("np", "numpy", "nprandom"):
        return False
    return "random" in chain[:-1] or chain[0] == "jr"


def _device_call_kind(call: ast.Call) -> Optional[str]:
    """"dev" for a device-value-producing call, "fetched" for
    ``jax.device_get`` (host values, but straight off a transfer)."""
    chain = _attr_chain(call.func)
    if not chain:
        return None
    if chain[0] == "jax":
        if len(chain) >= 2 and chain[1] == "device_get":
            return "fetched"
        if len(chain) >= 2 and chain[1] in _JAX_HOST:
            return None
        return "dev"
    if chain[0] in _DEVICE_ROOTS:
        return "dev"
    return None


def _target_names(target: ast.expr) -> List[str]:
    return [e.id for e in ast.walk(target) if isinstance(e, ast.Name)]


def _terminates(stmts: List[ast.stmt]) -> bool:
    """Does control flow definitely leave this statement list?"""
    return any(isinstance(s, (ast.Return, ast.Raise, ast.Break,
                              ast.Continue)) for s in stmts)


def _local_names(fi: FuncInfo) -> Set[str]:
    """Parameter + locally-bound names of a function (shallow)."""
    node = fi.node
    names: Set[str] = set(fi.params)
    a = node.args
    for extra in ([a.vararg] if a.vararg else []) + \
                 ([a.kwarg] if a.kwarg else []) + list(a.kwonlyargs):
        names.add(extra.arg if not isinstance(extra, str) else extra)
    for n in shallow_walk(node):
        if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            tgts = n.targets if isinstance(n, ast.Assign) else [n.target]
            for t in tgts:
                names.update(_target_names(t))
        elif isinstance(n, (ast.For, ast.AsyncFor)):
            names.update(_target_names(n.target))
        elif isinstance(n, ast.comprehension):
            names.update(_target_names(n.target))
        elif isinstance(n, ast.withitem) and n.optional_vars is not None:
            names.update(_target_names(n.optional_vars))
        elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            names.add(n.name)
        elif isinstance(n, ast.ExceptHandler) and n.name:
            names.add(n.name)
    return names


# =========================================================================
@register_rule
class R000SuppressionHygiene(Rule):
    id = "R000"
    name = "suppression-without-justification"
    rationale = ("a `# jaxlint: disable=` without a reason hides risk "
                 "silently; audits need the why next to the what")

    def check(self, module: Module, graph: ScopeGraph) -> Iterator[Finding]:
        known = set(rule_ids())
        for sup in module.suppressions:
            snippet = module.lines[sup.line - 1].strip() \
                if sup.line <= len(module.lines) else ""
            if not sup.rules:
                yield Finding(self.id, module.rel, sup.line, 0,
                              "jaxlint suppression names no rule ids "
                              "(expected `# jaxlint: disable=R0xx — "
                              "<reason>`)", snippet)
                continue
            bad = [r for r in sup.rules if r not in known]
            if bad:
                yield Finding(self.id, module.rel, sup.line, 0,
                              f"jaxlint suppression names unknown rule "
                              f"id(s) {bad}", snippet)
            if not sup.reason:
                yield Finding(self.id, module.rel, sup.line, 0,
                              f"jaxlint suppression of "
                              f"{','.join(sup.rules)} has no justification "
                              "— write `# jaxlint: disable=R0xx — "
                              "<reason>`", snippet)


# =========================================================================
@register_rule
class R001PrngKeyReuse(Rule):
    id = "R001"
    name = "prng-key-reuse"
    rationale = ("the same PRNG key consumed by two samplers yields "
                 "identical \"random\" draws — split/fold_in first")

    def check(self, module: Module, graph: ScopeGraph) -> Iterator[Finding]:
        for fi in graph.module_functions(module):
            if isinstance(fi.node, ast.Lambda):
                continue
            yield from self._check_func(module, fi)

    def _check_func(self, module: Module, fi: FuncInfo
                    ) -> Iterator[Finding]:
        key_names: Set[str] = {p for p in fi.params
                               if "key" in p.lower() or "rng" in p.lower()}
        counts: Dict[str, int] = {}
        reported: Set[int] = set()
        findings: List[Finding] = []

        def is_key_producer(value: ast.expr) -> bool:
            if isinstance(value, ast.Call):
                tail = last_name(value.func)
                if tail in _KEY_PRODUCERS and \
                        _is_jax_random(value.func, tail):
                    return True
            if isinstance(value, ast.Subscript):
                inner = value.value
                if isinstance(inner, ast.Name) and inner.id in key_names:
                    return True                  # rows of a key batch
            return False

        def consume(call: ast.Call) -> None:
            tail = last_name(call.func)
            if tail not in _SAMPLERS or not _is_jax_random(call.func, tail):
                return
            if not call.args:
                return
            arg = call.args[0]
            if isinstance(arg, ast.Name) and arg.id in key_names:
                counts[arg.id] = counts.get(arg.id, 0) + 1
                if counts[arg.id] > 1 and id(call) not in reported:
                    reported.add(id(call))
                    findings.append(self.finding(
                        module, call,
                        f"PRNG key `{arg.id}` is consumed again by "
                        f"jax.random.{tail} without an intervening "
                        "split/fold_in — identical draws"))

        def scan_calls(node: ast.AST) -> None:
            # shallow_walk yields descendants only — the expression itself
            # may already be the consuming Call
            if isinstance(node, ast.Call):
                consume(node)
            for n in shallow_walk(node):
                if isinstance(n, ast.Call):
                    consume(n)

        def exec_stmts(stmts: List[ast.stmt]) -> None:
            for s in stmts:
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                    continue
                if isinstance(s, ast.If):
                    scan_calls(s.test)
                    base = dict(counts)
                    exec_stmts(s.body)
                    after_body = dict(counts)
                    counts.clear()
                    counts.update(base)
                    exec_stmts(s.orelse)
                    if _terminates(s.orelse):
                        counts.clear()
                        counts.update(base)
                    if not _terminates(s.body):
                        # branch merge: max (a terminating branch — e.g.
                        # `if how == "uniform": return uniform(key)` —
                        # never reaches the fall-through consumption)
                        for k, v in after_body.items():
                            counts[k] = max(counts.get(k, 0), v)
                    continue
                if isinstance(s, (ast.For, ast.While)):
                    scan_calls(s.iter if isinstance(s, ast.For) else s.test)
                    exec_stmts(s.body)    # twice: loop-carried reuse
                    exec_stmts(s.body)
                    exec_stmts(s.orelse)
                    continue
                if isinstance(s, ast.Try):
                    exec_stmts(s.body)
                    for h in s.handlers:
                        exec_stmts(h.body)
                    exec_stmts(s.orelse)
                    exec_stmts(s.finalbody)
                    continue
                if isinstance(s, ast.With):
                    for item in s.items:
                        scan_calls(item.context_expr)
                    exec_stmts(s.body)
                    continue
                if isinstance(s, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    if s.value is not None:
                        scan_calls(s.value)
                    produced = s.value is not None and \
                        is_key_producer(s.value)
                    tgts = (s.targets if isinstance(s, ast.Assign)
                            else [s.target])
                    for t in tgts:
                        for name in _target_names(t):
                            counts[name] = 0     # reassignment resets
                            if produced:
                                key_names.add(name)
                    continue
                scan_calls(s)

        exec_stmts(fi.node.body)
        yield from findings


# =========================================================================
@register_rule
class R002HostSyncInHotLoop(Rule):
    id = "R002"
    name = "host-sync-in-hot-loop"
    rationale = ("per-value float()/.item()/device_get in a step loop "
                 "serializes host/device round-trips (the PR-5 class: ~8 "
                 "syncs per train step) — fetch once, convert once")

    #: conversions of device/fetched values inside one loop body are only
    #: flagged from this count on (a single fetch per iteration is the
    #: sanctioned pattern; the bug class is per-METRIC fan-out)
    LOOP_SYNC_THRESHOLD = 2

    def check(self, module: Module, graph: ScopeGraph) -> Iterator[Finding]:
        for fi in graph.module_functions(module):
            if graph.is_traced(fi) or isinstance(fi.node, ast.Lambda):
                continue
            yield from self._check_body(module, graph, fi, fi.node.body)
        yield from self._check_body(module, graph, None, module.tree.body)

    # ------------------------------------------------------------------
    def _check_body(self, module: Module, graph: ScopeGraph,
                    fi: Optional[FuncInfo], body: List[ast.stmt]
                    ) -> Iterator[Finding]:
        env: Dict[str, str] = {}           # name -> "dev" | "fetched"
        findings: List[Finding] = []
        reported: Set[int] = set()

        def kind_of(e: ast.expr) -> Optional[str]:
            if isinstance(e, ast.Name):
                return env.get(e.id)
            if isinstance(e, ast.Call):
                k = _device_call_kind(e)
                if k:
                    return k
                tgts = graph.resolve_call(e, module, fi)
                if any(graph.is_traced(t) for t in tgts):
                    return "dev"          # direct call into a jitted scope
                if isinstance(e.func, ast.Attribute):
                    return kind_of(e.func.value)   # m.items(), x.copy()
                return None
            if isinstance(e, ast.Attribute):
                if e.attr in _STATIC_ATTRS:
                    return None
                return kind_of(e.value)
            if isinstance(e, ast.Subscript):
                return kind_of(e.value)
            if isinstance(e, ast.BinOp):
                return _max_kind(kind_of(e.left), kind_of(e.right))
            if isinstance(e, ast.UnaryOp):
                return kind_of(e.operand)
            if isinstance(e, (ast.Tuple, ast.List)):
                k = None
                for el in e.elts:
                    k = _max_kind(k, kind_of(el))
                return k
            if isinstance(e, ast.IfExp):
                return _max_kind(kind_of(e.body), kind_of(e.orelse))
            if isinstance(e, ast.Starred):
                return kind_of(e.value)
            return None

        def contains_dev_call(e: ast.expr) -> bool:
            return any(isinstance(n, ast.Call)
                       and _device_call_kind(n) == "dev"
                       for n in ast.walk(e))

        def sync_candidates(node: ast.AST):
            """(call, arg_expr, what) for sync-shaped calls under node."""
            for n in shallow_walk(node):
                if not isinstance(n, ast.Call):
                    continue
                if isinstance(n.func, ast.Name) \
                        and n.func.id in _SYNC_BUILTINS \
                        and len(n.args) == 1:
                    yield n, n.args[0], n.func.id + "()"
                elif isinstance(n.func, ast.Attribute) \
                        and n.func.attr in _SYNC_METHODS and not n.args:
                    yield n, n.func.value, "." + n.func.attr + "()"
                elif isinstance(n.func, ast.Attribute) \
                        and n.func.attr in ("asarray", "array") \
                        and root_name(n.func) in ("np", "numpy") \
                        and n.args:
                    yield n, n.args[0], "np." + n.func.attr + "()"

        def flag(call: ast.Call, msg: str) -> None:
            if id(call) not in reported:
                reported.add(id(call))
                findings.append(self.finding(module, call, msg))

        def check_stmt_syncs(s: ast.stmt, in_loop: bool) -> None:
            # direct sync fused onto device compute: flagged anywhere
            for call, arg, what in sync_candidates(s):
                if contains_dev_call(arg):
                    flag(call, f"{what} on a freshly computed device value "
                               "forces an extra host sync — compute from "
                               "an already-fetched array (np) or keep it "
                               "on device")

        def loop_syncs(loop: ast.stmt) -> None:
            """Per-value conversions + repeated device_gets in one loop."""
            tainted: List[Tuple[ast.Call, str, str]] = []
            for call, arg, what in sync_candidates(loop):
                k = kind_of(arg)
                if k is None and contains_dev_call(arg):
                    k = "dev"
                if k is not None:
                    tainted.append((call, what, k))
            if len(tainted) >= self.LOOP_SYNC_THRESHOLD:
                for call, what, k in tainted:
                    origin = ("on a device value" if k == "dev" else
                              "on an already-fetched value")
                    flag(call, f"{what} {origin} inside a hot loop — "
                               f"{len(tainted)} per-value host conversions "
                               "per iteration; fetch the whole pytree with "
                               "ONE jax.device_get and convert at the "
                               "transfer site")
            gets = [n for n in shallow_walk(loop)
                    if isinstance(n, ast.Call)
                    and _device_call_kind(n) == "fetched"]
            if len(gets) >= 2:
                for g in gets:
                    flag(g, f"{len(gets)} jax.device_get transfers per "
                            "loop iteration — batch them into one "
                            "device_get of a tuple/dict")

        def walk_stmts(stmts: List[ast.stmt], in_loop: bool) -> None:
            for s in stmts:
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                    continue
                if isinstance(s, (ast.For, ast.While)):
                    if isinstance(s, ast.For):
                        k = kind_of(s.iter)
                        if k:
                            for name in _target_names(s.target):
                                env[name] = k
                    walk_stmts(s.body, True)   # pass 1: establish taint
                    if in_loop is False:
                        loop_syncs(s)          # ...then scan the loop
                    check_stmt_syncs(s, True)
                    walk_stmts(s.body, True)   # pass 2: loop-carried
                    walk_stmts(s.orelse, in_loop)
                    continue
                check_stmt_syncs(s, in_loop)
                if in_loop:
                    # nested-loop bodies re-checked with taint present
                    pass
                if isinstance(s, (ast.Assign, ast.AnnAssign)):
                    if s.value is not None:
                        k = kind_of(s.value)
                        tgts = (s.targets if isinstance(s, ast.Assign)
                                else [s.target])
                        for t in tgts:
                            for name in _target_names(t):
                                if k:
                                    env[name] = k
                                else:
                                    env.pop(name, None)
                elif isinstance(s, ast.If):
                    walk_stmts(s.body, in_loop)
                    walk_stmts(s.orelse, in_loop)
                elif isinstance(s, ast.Try):
                    walk_stmts(s.body, in_loop)
                    for h in s.handlers:
                        walk_stmts(h.body, in_loop)
                    walk_stmts(s.orelse, in_loop)
                    walk_stmts(s.finalbody, in_loop)
                elif isinstance(s, ast.With):
                    walk_stmts(s.body, in_loop)

        walk_stmts(body, False)
        yield from findings


def _max_kind(a: Optional[str], b: Optional[str]) -> Optional[str]:
    order = {None: 0, "fetched": 1, "dev": 2}
    return a if order[a] >= order[b] else b


# =========================================================================
@register_rule
class R003MutableClosureCapture(Rule):
    id = "R003"
    name = "mutable-closure-capture"
    rationale = ("jit bakes closure-captured values in as trace-time "
                 "constants: later mutations are invisible (the PR-2 NFT "
                 "frozen-reference bug) — thread them as arguments")

    def check(self, module: Module, graph: ScopeGraph) -> Iterator[Finding]:
        for fi in graph.module_functions(module):
            if not graph.is_traced(fi):
                continue
            yield from self._check_self_reads(module, graph, fi)
            if fi.parent is not None:
                yield from self._check_nonlocal(module, graph, fi)

    def _check_self_reads(self, module: Module, graph: ScopeGraph,
                          fi: FuncInfo) -> Iterator[Finding]:
        cls_name = _enclosing_class(fi)
        if not cls_name:
            return
        own_writes: Set[str] = set()
        reads: List[Tuple[str, ast.Attribute]] = []
        seen_attrs: Set[str] = set()
        for n in shallow_walk(fi.node):
            if isinstance(n, ast.Attribute) and \
                    isinstance(n.value, ast.Name) and n.value.id == "self":
                if isinstance(n.ctx, ast.Store):
                    own_writes.add(n.attr)
                elif n.attr not in seen_attrs:
                    seen_attrs.add(n.attr)
                    reads.append((n.attr, n))
        for attr, node in reads:
            if attr in own_writes:
                continue       # this function IS the mutation site
            writers = graph.family_attr_writers(cls_name, attr)
            writers -= {"__init__", "__post_init__", fi.name}
            if writers:
                yield self.finding(
                    module, node,
                    f"traced scope `{fi.qualname}` reads `self.{attr}`, "
                    f"which {sorted(writers)} mutate after __init__ — jit "
                    "captures the trace-time value as a constant and "
                    "never sees the update; pass it as an argument "
                    "(update_extras-style)")

    def _check_nonlocal(self, module: Module, graph: ScopeGraph,
                        fi: FuncInfo) -> Iterator[Finding]:
        local = _local_names(fi)
        explicit_nonlocal: Set[str] = {
            name for n in shallow_walk(fi.node)
            if isinstance(n, ast.Nonlocal) for name in n.names}
        free_reads: Dict[str, ast.Name] = {}
        for n in shallow_walk(fi.node):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                    and n.id not in local \
                    and n.id not in explicit_nonlocal \
                    and n.id not in free_reads:
                free_reads[n.id] = n
        parent = fi.parent
        def_line = fi.node.lineno
        while parent is not None:
            for n in shallow_walk(parent.node):
                if not isinstance(n, (ast.Assign, ast.AugAssign)):
                    continue
                if n.lineno <= def_line:
                    continue
                tgts = (n.targets if isinstance(n, ast.Assign)
                        else [n.target])
                for t in tgts:
                    for name in _target_names(t):
                        if name in free_reads and name != fi.name:
                            yield self.finding(
                                module, free_reads.pop(name),
                                f"traced closure `{fi.qualname}` captures "
                                f"`{name}`, reassigned at line {n.lineno} "
                                "after the definition — the trace keeps "
                                "the old value; pass it as an argument")
            parent = parent.parent


# =========================================================================
@register_rule
class R004PythonControlFlowOnTracer(Rule):
    id = "R004"
    name = "python-control-flow-on-tracer"
    rationale = ("`if`/`while` on a jnp-derived value inside a traced "
                 "scope raises at trace time (or silently specializes) — "
                 "use lax.cond/lax.select/jnp.where")

    def check(self, module: Module, graph: ScopeGraph) -> Iterator[Finding]:
        for fi in graph.module_functions(module):
            if not graph.is_traced(fi) or isinstance(fi.node, ast.Lambda):
                continue
            yield from self._check_func(module, fi)

    def _check_func(self, module: Module, fi: FuncInfo
                    ) -> Iterator[Finding]:
        env: Set[str] = set()
        findings: List[Finding] = []
        reported: Set[int] = set()

        def tainted(e: ast.expr) -> bool:
            if isinstance(e, ast.Name):
                return e.id in env
            if isinstance(e, ast.Call):
                if _device_call_kind(e) == "dev":
                    return True
                if isinstance(e.func, ast.Attribute) \
                        and e.func.attr in _ARRAY_REDUCERS \
                        and tainted(e.func.value):
                    return True
                return False
            if isinstance(e, ast.Attribute):
                if e.attr in _STATIC_ATTRS:
                    return False
                return tainted(e.value)
            if isinstance(e, ast.Subscript):
                return tainted(e.value)
            if isinstance(e, ast.Compare):
                if all(isinstance(op, (ast.Is, ast.IsNot)) for op in e.ops):
                    return False           # `is None` checks are static
                return tainted(e.left) or any(tainted(c)
                                              for c in e.comparators)
            if isinstance(e, ast.BoolOp):
                return any(tainted(v) for v in e.values)
            if isinstance(e, ast.BinOp):
                return tainted(e.left) or tainted(e.right)
            if isinstance(e, ast.UnaryOp):
                return tainted(e.operand)
            if isinstance(e, (ast.Tuple, ast.List)):
                return any(tainted(el) for el in e.elts)
            if isinstance(e, ast.IfExp):
                return tainted(e.body) or tainted(e.orelse)
            return False

        def flag(node: ast.AST, what: str) -> None:
            if id(node) not in reported:
                reported.add(id(node))
                findings.append(self.finding(
                    module, node,
                    f"Python `{what}` on a traced (jnp-derived) value "
                    f"inside traced scope `{fi.qualname}` — this "
                    "concretizes a tracer; use lax.cond / lax.while_loop "
                    "/ jnp.where"))

        def walk_stmts(stmts: List[ast.stmt]) -> None:
            for s in stmts:
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                    continue
                if isinstance(s, (ast.Assign, ast.AnnAssign)):
                    if s.value is not None:
                        is_t = tainted(s.value)
                        tgts = (s.targets if isinstance(s, ast.Assign)
                                else [s.target])
                        for t in tgts:
                            for name in _target_names(t):
                                (env.add if is_t else env.discard)(name)
                elif isinstance(s, ast.AugAssign):
                    if tainted(s.value):
                        env.update(_target_names(s.target))
                elif isinstance(s, ast.If):
                    if tainted(s.test):
                        flag(s, "if")
                    walk_stmts(s.body)
                    walk_stmts(s.orelse)
                elif isinstance(s, ast.While):
                    if tainted(s.test):
                        flag(s, "while")
                    walk_stmts(s.body)
                    walk_stmts(s.body)
                elif isinstance(s, ast.For):
                    walk_stmts(s.body)
                    walk_stmts(s.body)
                    walk_stmts(s.orelse)
                elif isinstance(s, ast.Try):
                    walk_stmts(s.body)
                    for h in s.handlers:
                        walk_stmts(h.body)
                    walk_stmts(s.orelse)
                    walk_stmts(s.finalbody)
                elif isinstance(s, ast.With):
                    walk_stmts(s.body)
                # assert/return/expr: only if/while are the hazard

        walk_stmts(fi.node.body)
        yield from findings


# =========================================================================
@register_rule
class R005DonatedBufferReuse(Rule):
    id = "R005"
    name = "donated-buffer-reuse"
    rationale = ("an argument passed through a donate_argnums position is "
                 "deallocated by XLA — reading it afterwards returns "
                 "garbage or raises")

    def check(self, module: Module, graph: ScopeGraph) -> Iterator[Finding]:
        donators = self._class_donators(module, graph)
        for fi in graph.module_functions(module):
            if isinstance(fi.node, ast.Lambda):
                continue
            yield from self._check_func(module, graph, fi, donators)

    # which `self.<attr>` / names hold donating jitted callables
    def _donating_call(self, call: ast.Call, module: Module,
                       graph: ScopeGraph, fi: Optional[FuncInfo]
                       ) -> Optional[Set[int]]:
        if last_name(call.func) in ("jit", "pjit"):
            pos = _donated_positions(call)
            return pos or None
        for target in graph.resolve_call(call, module, fi):
            pos = graph.wrapper_donates.get(id(target.node))
            if pos:
                return pos
        return None

    def _class_donators(self, module: Module, graph: ScopeGraph
                        ) -> Dict[str, Dict[str, Set[int]]]:
        out: Dict[str, Dict[str, Set[int]]] = {}
        for fi in graph.module_functions(module):
            if isinstance(fi.node, ast.Lambda) or fi.class_name is None:
                continue
            for n in shallow_walk(fi.node):
                if isinstance(n, ast.Assign) and \
                        isinstance(n.value, ast.Call):
                    pos = self._donating_call(n.value, module, graph, fi)
                    if not pos:
                        continue
                    for t in n.targets:
                        if isinstance(t, ast.Attribute) and \
                                isinstance(t.value, ast.Name) and \
                                t.value.id == "self":
                            out.setdefault(fi.class_name, {})[t.attr] = pos
        return out

    def _check_func(self, module: Module, graph: ScopeGraph, fi: FuncInfo,
                    donators: Dict[str, Dict[str, Set[int]]]
                    ) -> Iterator[Finding]:
        local_don: Dict[str, Set[int]] = {}
        donated: Dict[str, Tuple[ast.Call, str]] = {}  # expr-src -> origin
        findings: List[Finding] = []

        def call_donates(call: ast.Call) -> Optional[Set[int]]:
            # donation happens when a donating CALLABLE is invoked — the
            # `jax.jit(fn, donate_argnums=...)` constructor itself donates
            # nothing
            f = call.func
            if isinstance(f, ast.Name) and f.id in local_don:
                return local_don[f.id]
            if isinstance(f, ast.Attribute) and \
                    isinstance(f.value, ast.Name) and f.value.id == "self" \
                    and fi.class_name:
                for cname in graph.family(fi.class_name):
                    hit = donators.get(cname, {}).get(f.attr)
                    if hit:
                        return hit
            if isinstance(f, ast.Call):       # jax.jit(g, donate...)(x)
                return self._donating_call(f, module, graph, fi)
            return None

        def expr_src(e: ast.expr) -> Optional[str]:
            if isinstance(e, ast.Name):
                return e.id
            if isinstance(e, ast.Attribute) and \
                    isinstance(e.value, ast.Name):
                return f"{e.value.id}.{e.attr}"
            return None

        def walk_stmts(stmts: List[ast.stmt],
                       donated: Dict[str, Tuple[ast.Call, str]]) -> None:
            for s in stmts:
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                    continue
                # 1. reads of already-donated buffers in this statement
                for n in shallow_walk(s):
                    if isinstance(n, (ast.Name, ast.Attribute)) and \
                            isinstance(getattr(n, "ctx", None), ast.Load):
                        src = expr_src(n)
                        if src in donated:
                            call, label = donated.pop(src)
                            findings.append(self.finding(
                                module, n,
                                f"`{src}` was donated to `{label}` (its "
                                "buffer may already be deallocated) — "
                                "reading it afterwards is invalid; use "
                                "the returned value or drop the "
                                "donation"))
                # 2. does this statement donate something?  (a Return's
                # donation can never be read afterwards — skip it)
                new_donations: List[Tuple[str, ast.Call, str]] = []
                for n in (() if isinstance(s, ast.Return)
                          else shallow_walk(s)):
                    if not isinstance(n, ast.Call):
                        continue
                    pos = call_donates(n)
                    if not pos:
                        continue
                    label = ".".join(_attr_chain(n.func)) or "jitted call"
                    for p in sorted(pos):
                        if p < len(n.args):
                            src = expr_src(n.args[p])
                            if src:
                                new_donations.append((src, n, label))
                # 3. track donating-callable bindings + reassignments
                if isinstance(s, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                    tgts = (s.targets if isinstance(s, ast.Assign)
                            else [s.target])
                    assigned = set()
                    for t in tgts:
                        src = expr_src(t)
                        if src:
                            assigned.add(src)
                            donated.pop(src, None)
                        for name in _target_names(t):
                            donated.pop(name, None)
                    value = getattr(s, "value", None)
                    if isinstance(value, ast.Call):
                        pos = self._donating_call(value, module, graph, fi)
                        if pos:
                            for t in tgts:
                                if isinstance(t, ast.Name):
                                    local_don[t.id] = pos
                    for src, call, label in new_donations:
                        if src not in assigned:
                            donated[src] = (call, label)
                else:
                    for src, call, label in new_donations:
                        donated[src] = (call, label)
                # recurse, branch-local copies for If
                if isinstance(s, ast.If):
                    d1, d2 = dict(donated), dict(donated)
                    walk_stmts(s.body, d1)
                    walk_stmts(s.orelse, d2)
                    donated.update(d1)
                    donated.update(d2)
                elif isinstance(s, (ast.For, ast.While)):
                    walk_stmts(s.body, donated)
                    walk_stmts(s.orelse, donated)
                elif isinstance(s, ast.Try):
                    walk_stmts(s.body, donated)
                    for h in s.handlers:
                        walk_stmts(h.body, donated)
                    walk_stmts(s.orelse, donated)
                    walk_stmts(s.finalbody, donated)
                elif isinstance(s, ast.With):
                    walk_stmts(s.body, donated)

        walk_stmts(fi.node.body, donated)
        yield from findings


# =========================================================================
@register_rule
class R006RecompileHazard(Rule):
    id = "R006"
    name = "recompile-hazard"
    rationale = ("dict/list literals flowing into static_argnums/names "
                 "(unhashable -> TypeError or retrace-per-call) and "
                 "jax.jit built inside a loop both defeat the compile "
                 "cache")

    def check(self, module: Module, graph: ScopeGraph) -> Iterator[Finding]:
        statics = self._static_map(module, graph)
        for fi in graph.module_functions(module):
            if isinstance(fi.node, ast.Lambda):
                continue
            yield from self._check_func(module, graph, fi, statics)
        yield from self._check_jit_in_loop(module, None, module.tree.body,
                                           graph)

    # map: id(FuncInfo.node) -> (static positions, static names)
    def _static_map(self, module: Module, graph: ScopeGraph
                    ) -> Dict[int, Tuple[Set[int], Set[str]]]:
        out: Dict[int, Tuple[Set[int], Set[str]]] = {}
        for fi in graph.module_functions(module):
            if isinstance(fi.node, ast.Lambda):
                continue
            for dec in getattr(fi.node, "decorator_list", []):
                if not isinstance(dec, ast.Call):
                    continue
                target = dec
                if last_name(dec.func) == "partial" and dec.args \
                        and last_name(dec.args[0]) in ("jit", "pjit"):
                    target = dec
                elif last_name(dec.func) not in ("jit", "pjit"):
                    continue
                pos, names = _static_spec(target)
                if pos or names:
                    out[id(fi.node)] = (pos, names)
        return out

    def _check_func(self, module: Module, graph: ScopeGraph, fi: FuncInfo,
                    statics: Dict[int, Tuple[Set[int], Set[str]]]
                    ) -> Iterator[Finding]:
        unhashable: Set[str] = set()
        for n in shallow_walk(fi.node):
            if isinstance(n, ast.Assign) and \
                    isinstance(n.value, _UNHASHABLE_LITERALS):
                for t in n.targets:
                    unhashable.update(_target_names(t))

        def is_unhashable(e: ast.expr) -> bool:
            return isinstance(e, _UNHASHABLE_LITERALS) or (
                isinstance(e, ast.Name) and e.id in unhashable)

        for n in shallow_walk(fi.node):
            if not isinstance(n, ast.Call):
                continue
            # (a) literal static spec on a direct jit call with args known
            if last_name(n.func) in ("jit", "pjit"):
                pos, names = _static_spec(n)
                _ = pos, names     # positions checked at call sites below
            # (b) call sites of statically-decorated functions
            for target in graph.resolve_call(n, module, fi):
                spec = statics.get(id(target.node))
                if not spec:
                    continue
                s_pos, s_names = spec
                for kw in n.keywords:
                    if kw.arg in s_names and is_unhashable(kw.value):
                        yield self.finding(
                            module, n,
                            f"unhashable value for static arg "
                            f"`{kw.arg}` of `{target.name}` — every call "
                            "re-traces (or raises TypeError); pass a "
                            "hashable (tuple/frozen) value")
                for p in s_pos:
                    if p < len(n.args) and is_unhashable(n.args[p]):
                        yield self.finding(
                            module, n,
                            f"unhashable value in static_argnums position "
                            f"{p} of `{target.name}` — every call "
                            "re-traces (or raises TypeError)")
        yield from self._check_jit_in_loop(module, fi, fi.node.body, graph)

    def _check_jit_in_loop(self, module: Module, fi: Optional[FuncInfo],
                           body: List[ast.stmt], graph: ScopeGraph
                           ) -> Iterator[Finding]:
        if fi is not None and graph.is_traced(fi):
            return
        for s in body:
            for n in shallow_walk(s):
                if isinstance(n, (ast.For, ast.While)):
                    for inner in shallow_walk(n):
                        if isinstance(inner, ast.Call) \
                                and last_name(inner.func) in ("jit", "pjit") \
                                and _attr_chain(inner.func)[0] in ("jax",
                                                                   "jit",
                                                                   "pjit"):
                            yield self.finding(
                                module, inner,
                                "jax.jit called inside a loop builds a "
                                "fresh callable (and re-traces) every "
                                "iteration — hoist the jit out of the "
                                "loop")


def _static_spec(call: ast.Call) -> Tuple[Set[int], Set[str]]:
    pos: Set[int] = set()
    names: Set[str] = set()
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            v = kw.value
            if isinstance(v, ast.Tuple):
                pos |= {e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)}
            elif isinstance(v, ast.Constant) and isinstance(v.value, int):
                pos.add(v.value)
        elif kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, (ast.Tuple, ast.List)):
                names |= {e.value for e in v.elts
                          if isinstance(e, ast.Constant)
                          and isinstance(e.value, str)}
            elif isinstance(v, ast.Constant) and isinstance(v.value, str):
                names.add(v.value)
    return pos, names


@register_rule
class R007BlockingDrainInDispatchLoop(Rule):
    id = "R007"
    name = "blocking-drain-in-dispatch-loop"
    rationale = ("device_get/block_until_ready/float() on the output of "
                 "the jit step dispatched in the SAME loop iteration "
                 "serializes host and device (the pre-pipeline TrainLoop "
                 "shape) — buffer results and drain them a step late")

    def check(self, module: Module, graph: ScopeGraph) -> Iterator[Finding]:
        class_disp = self._class_dispatchers(module, graph)
        for fi in graph.module_functions(module):
            if graph.is_traced(fi) or isinstance(fi.node, ast.Lambda):
                continue
            yield from self._check_func(module, graph, fi, class_disp)

    # ---------------------------------------------------------- dispatchers
    def _dispatching_ctor(self, call: ast.Call, module: Module,
                          graph: ScopeGraph, fi: Optional[FuncInfo]) -> bool:
        """Does evaluating ``call`` build a jit-dispatching callable —
        ``jax.jit(...)`` / ``pjit(...)`` directly, or any function of the
        ``distributed.jit_*`` wrapper layer (ScopeGraph already knows which
        functions trace a parameter)?"""
        if last_name(call.func) in ("jit", "pjit"):
            return True
        return any(graph.wrapper_positions.get(id(t.node))
                   for t in graph.resolve_call(call, module, fi))

    def _class_dispatchers(self, module: Module, graph: ScopeGraph
                           ) -> Dict[str, Set[str]]:
        """class -> ``self.<attr>``s holding jitted callables."""
        out: Dict[str, Set[str]] = {}
        for fi in graph.module_functions(module):
            if isinstance(fi.node, ast.Lambda) or fi.class_name is None:
                continue
            for n in shallow_walk(fi.node):
                if not (isinstance(n, ast.Assign)
                        and isinstance(n.value, ast.Call)
                        and self._dispatching_ctor(n.value, module, graph,
                                                   fi)):
                    continue
                for t in n.targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id == "self":
                        out.setdefault(fi.class_name, set()).add(t.attr)
        return out

    def _local_dispatchers(self, module: Module, graph: ScopeGraph,
                           fi: FuncInfo) -> Set[str]:
        """Names in ``fi`` bound to jitted callables."""
        out: Set[str] = set()
        for n in shallow_walk(fi.node):
            if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call) \
                    and self._dispatching_ctor(n.value, module, graph, fi):
                out.update(t.id for t in n.targets
                           if isinstance(t, ast.Name))
        return out

    # ------------------------------------------------------------ the walk
    def _check_func(self, module: Module, graph: ScopeGraph, fi: FuncInfo,
                    class_disp: Dict[str, Set[str]]) -> Iterator[Finding]:
        local_disp = self._local_dispatchers(module, graph, fi)
        findings: List[Finding] = []
        reported: Set[int] = set()

        def is_dispatch(call: ast.Call) -> bool:
            f = call.func
            if isinstance(f, ast.Name) and f.id in local_disp:
                return True
            if isinstance(f, ast.Attribute) and \
                    isinstance(f.value, ast.Name) and f.value.id == "self" \
                    and fi.class_name:
                if any(f.attr in class_disp.get(c, ())
                       for c in graph.family(fi.class_name)):
                    return True
            if isinstance(f, ast.Call):        # jax.jit(g)(x)
                return self._dispatching_ctor(f, module, graph, fi)
            tgts = graph.resolve_call(call, module, fi)
            return bool(tgts) and any(graph.is_traced(t) for t in tgts)

        def dispatched_in(e: ast.expr, hot: Set[str]) -> bool:
            return any(
                (isinstance(n, ast.Name) and n.id in hot)
                or (isinstance(n, ast.Call) and is_dispatch(n))
                for n in ast.walk(e))

        def sync_shapes(s: ast.stmt):
            """(call, drained_expr, label) for blocking fetches under s."""
            for n in shallow_walk(s):
                if not isinstance(n, ast.Call):
                    continue
                chain = _attr_chain(n.func)
                if chain and chain[-1] == "device_get" \
                        and chain[0] == "jax" and n.args:
                    yield n, n.args[0], "jax.device_get()"
                elif chain and chain[-1] == "block_until_ready":
                    if chain[0] == "jax" and n.args:
                        yield n, n.args[0], "jax.block_until_ready()"
                    elif not n.args and isinstance(n.func, ast.Attribute):
                        yield n, n.func.value, ".block_until_ready()"
                elif isinstance(n.func, ast.Name) \
                        and n.func.id in _SYNC_BUILTINS and len(n.args) == 1:
                    yield n, n.args[0], n.func.id + "()"
                elif isinstance(n.func, ast.Attribute) \
                        and n.func.attr in _SYNC_METHODS and not n.args:
                    yield n, n.func.value, "." + n.func.attr + "()"

        def targets_of(s: ast.stmt) -> List[str]:
            if isinstance(s, ast.Assign):
                names: List[str] = []
                for t in s.targets:
                    if isinstance(t, ast.Name):
                        names.append(t.id)
                    elif isinstance(t, (ast.Tuple, ast.List)):
                        names.extend(e.id for e in t.elts
                                     if isinstance(e, ast.Name))
                return names
            if isinstance(s, ast.AnnAssign) and \
                    isinstance(s.target, ast.Name) and s.value is not None:
                return [s.target.id]
            return []

        def handle(stmts: List[ast.stmt], hot: Set[str],
                   in_loop: bool) -> None:
            for s in stmts:
                if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                    continue
                if isinstance(s, (ast.For, ast.While, ast.AsyncFor)):
                    inner = set(hot)
                    handle(s.body, inner, True)
                    handle(s.orelse, set(hot), in_loop)
                    continue
                if isinstance(s, ast.If):
                    a, b = set(hot), set(hot)
                    handle(s.body, a, in_loop)
                    handle(s.orelse, b, in_loop)
                    hot |= a | b
                    continue
                if isinstance(s, (ast.With, ast.AsyncWith)):
                    handle(s.body, hot, in_loop)
                    continue
                if isinstance(s, ast.Try):
                    handle(s.body, hot, in_loop)
                    for h in s.handlers:
                        handle(h.body, set(hot), in_loop)
                    handle(s.orelse, hot, in_loop)
                    handle(s.finalbody, hot, in_loop)
                    continue
                if in_loop:
                    for call, arg, label in sync_shapes(s):
                        if id(call) in reported:
                            continue
                        if dispatched_in(arg, hot):
                            reported.add(id(call))
                            findings.append(self.finding(
                                module, call,
                                f"{label} on the output of the jit step "
                                "dispatched this iteration blocks until "
                                "the device finishes — dispatch runs "
                                "ahead only if results are buffered and "
                                "drained >=1 step late (deque) or after "
                                "the loop"))
                    names = targets_of(s)
                    hot.difference_update(names)
                    value = getattr(s, "value", None)
                    if names and isinstance(value, ast.Call) \
                            and is_dispatch(value):
                        hot.update(names)

        handle(fi.node.body, set(), False)
        yield from findings
