"""``python -m repro.analysis`` — drive the linter.

Exit status is 0 iff every finding is either inline-suppressed (with a
justification) or fingerprint-matched in the committed baseline.  Stale
baseline entries never fail the run but are always reported.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis import baseline as bl
from repro.analysis.core import Finding, Module, Suppression, all_rules
from repro.analysis.scopes import ScopeGraph

DEFAULT_PATHS = ["src/repro"]


def collect_files(paths: Sequence[str]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(
                f for f in path.rglob("*.py")
                if "__pycache__" not in f.parts
                and not any(part.startswith(".") for part in f.parts)))
        elif path.suffix == ".py":
            files.append(path)
    return files


def _rel(path: Path) -> str:
    try:
        return path.resolve().relative_to(Path.cwd()).as_posix()
    except ValueError:
        return path.as_posix()


def parse_modules(files: Sequence[Path]) -> Tuple[List[Module], List[str]]:
    modules: List[Module] = []
    errors: List[str] = []
    for f in files:
        try:
            modules.append(Module.parse(f, rel=_rel(f)))
        except SyntaxError as e:                      # pragma: no cover
            errors.append(f"{f}: {e}")
    return modules, errors


def run_modules(modules: Sequence[Module]
                ) -> Tuple[List[Finding], List[Finding], ScopeGraph]:
    """(reportable, suppressed, graph) over already-parsed modules."""
    graph = ScopeGraph(modules)
    sup_by_rel: Dict[str, List[Suppression]] = {
        m.rel: m.suppressions for m in modules}
    reportable: List[Finding] = []
    suppressed: List[Finding] = []
    for mod in modules:
        for rule in all_rules():
            for finding in rule.check(mod, graph):
                if any(s.covers(finding)
                       for s in sup_by_rel.get(finding.path, [])):
                    suppressed.append(finding)
                else:
                    reportable.append(finding)
    reportable.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    suppressed.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return reportable, suppressed, graph


def run_paths(paths: Sequence[str]
              ) -> Tuple[List[Finding], List[Finding], ScopeGraph]:
    """Convenience for tests: lint ``paths``, return (reportable,
    suppressed, graph)."""
    modules, _ = parse_modules(collect_files(paths))
    return run_modules(modules)


def _print_catalog() -> None:
    for rule in all_rules():
        print(f"{rule.id} {rule.name}")
        print(f"     {rule.rationale}")


def _print_suppressions(modules: Sequence[Module]) -> int:
    n = 0
    for mod in modules:
        for s in mod.suppressions:
            n += 1
            rules = ",".join(s.rules) or "<none>"
            reason = s.reason or "<MISSING JUSTIFICATION>"
            print(f"{mod.rel}:{s.line}: {rules} — {reason}")
    print(f"{n} suppression(s)")
    return 0


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="jaxlint: JAX-aware static analysis for this repo's "
                    "bug classes (stdlib-only, no jax import)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=bl.DEFAULT_BASELINE,
                    help="baseline file of accepted findings")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline file entirely")
    ap.add_argument("--update-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--list-suppressions", action="store_true",
                    help="list every inline suppression and exit")
    ap.add_argument("--catalog", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.catalog:
        _print_catalog()
        return 0

    t0 = time.monotonic()
    modules, errors = parse_modules(
        collect_files(args.paths or DEFAULT_PATHS))
    for e in errors:
        print(f"parse error: {e}", file=sys.stderr)

    if args.list_suppressions:
        return _print_suppressions(modules)

    findings, suppressed, _ = run_modules(modules)

    base_path = Path(args.baseline)
    if args.update_baseline:
        n = bl.save(base_path, findings)
        print(f"wrote {n} finding(s) to {base_path}")
        return 0

    base = {} if args.no_baseline else bl.load(base_path)
    new, matched, stale = bl.split(findings, base)

    dt = time.monotonic() - t0
    if args.format == "json":
        print(json.dumps({
            "new": [f.to_dict() for f in new],
            "baselined": [f.to_dict() for f in matched],
            "suppressed": [f.to_dict() for f in suppressed],
            "stale_baseline": stale,
            "files": len(modules),
            "seconds": round(dt, 3),
        }, indent=2))
    else:
        for f in new:
            print(f"{f.location()}: {f.rule} [{_rule_name(f.rule)}] "
                  f"{f.message}")
            if f.snippet:
                print(f"    {f.snippet}")
        for e in stale:
            print(f"stale baseline entry: {e['rule']} {e['path']} "
                  f"`{e['snippet']}` — no longer found, prune with "
                  "--update-baseline")
        print(f"jaxlint: {len(modules)} file(s), {len(new)} new, "
              f"{len(matched)} baselined, {len(suppressed)} suppressed, "
              f"{len(stale)} stale baseline entr(ies) [{dt:.2f}s]")
    return 1 if new else 0


def _rule_name(rule_id: str) -> str:
    for rule in all_rules():
        if rule.id == rule_id:
            return rule.name
    return "?"


if __name__ == "__main__":                            # pragma: no cover
    raise SystemExit(main())
