"""Shared plumbing for the ``jaxlint`` static analyzer.

Stdlib only (``ast``/``re``/``dataclasses``) — this module must import on a
bare interpreter with jax blocked (``scripts/check_deps.py`` enforces it),
so linting never pays jax's import or device-init cost.

Pieces:

- :class:`Finding` — one diagnostic (rule id, location, message, snippet).
- :class:`Rule` + :func:`register_rule` — the rule registry, mirroring the
  repro component registry idiom: a rule registers itself by id and the
  driver discovers it; adding a rule never touches the driver.
- :class:`Suppression` / :func:`parse_suppressions` — inline
  ``# jaxlint: disable=R00x — <why>`` comments.  A justification is
  *required*: a bare ``disable=`` is itself reported (rule R000) so
  accepted risk always carries its rationale in the diff.
- :class:`Module` — one parsed source file (ast + raw lines + its
  suppressions), the unit every rule's ``check`` receives.
"""
from __future__ import annotations

import ast
import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Tuple


@dataclass(frozen=True)
class Finding:
    rule: str          # "R002"
    path: str          # display path (relative to the lint root)
    line: int          # 1-based
    col: int           # 0-based
    message: str
    snippet: str = ""  # stripped source line, for fingerprints + review

    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_dict(self) -> Dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message,
                "snippet": self.snippet}


@dataclass(frozen=True)
class Suppression:
    path: str
    line: int            # line the comment sits on
    applies_to: int      # line the suppression covers (next line if the
                         # comment stands alone)
    rules: Tuple[str, ...]
    reason: str          # "" == unjustified -> R000

    def covers(self, finding: Finding) -> bool:
        return (finding.path == self.path
                and finding.line == self.applies_to
                and finding.rule in self.rules
                and bool(self.reason))


# --------------------------------------------------------------------- rules

class Rule:
    """Subclass contract: set ``id`` (R0xx), ``name`` (kebab-case) and
    ``rationale`` (one line, shown by ``--catalog``), and implement
    ``check(module, graph)`` yielding :class:`Finding`s.  Register with
    ``@register_rule`` — the driver picks it up automatically."""

    id: str = ""
    name: str = ""
    rationale: str = ""

    def check(self, module: "Module", graph) -> Iterator[Finding]:
        raise NotImplementedError

    def finding(self, module: "Module", node: ast.AST, message: str
                ) -> Finding:
        line = getattr(node, "lineno", 1)
        col = getattr(node, "col_offset", 0)
        snippet = ""
        if 1 <= line <= len(module.lines):
            snippet = module.lines[line - 1].strip()
        return Finding(self.id, module.rel, line, col, message, snippet)


_RULES: Dict[str, Rule] = {}
_ID_RE = re.compile(r"^R\d{3}$")


def register_rule(cls):
    """Class decorator: instantiate and register a :class:`Rule` by id."""
    inst = cls()
    if not _ID_RE.match(inst.id or ""):
        raise ValueError(f"rule id must match R\\d{{3}}, got {inst.id!r}")
    if not inst.name or not inst.rationale:
        raise ValueError(f"rule {inst.id} needs a name and a rationale")
    if inst.id in _RULES and type(_RULES[inst.id]) is not cls:
        raise ValueError(f"rule {inst.id} already registered")
    _RULES[inst.id] = inst
    return cls


def all_rules() -> List[Rule]:
    return [_RULES[rid] for rid in sorted(_RULES)]


def rule_ids() -> Tuple[str, ...]:
    return tuple(sorted(_RULES))


# -------------------------------------------------------------- suppressions

# Format: a hash, then ``jaxlint: disable=R001,R002 — reason`` ("--",
# "-" and ":" also accepted as the separator; the reason may not be
# empty).  Real COMMENT tokens only (via ``tokenize``) — the same text
# inside a docstring is prose.
_SUPPRESS_RE = re.compile(
    r"#\s*jaxlint:\s*disable=\s*([A-Za-z0-9,\s]*?)\s*"
    r"(?:(?:—|--|-|:)\s*(.*))?$")


def parse_suppressions(rel: str, source: str,
                       lines: List[str]) -> List[Suppression]:
    out: List[Suppression] = []
    try:
        tokens = list(tokenize.generate_tokens(
            io.StringIO(source).readline))
    except (tokenize.TokenError, IndentationError):  # pragma: no cover
        return out
    for tok in tokens:
        if tok.type != tokenize.COMMENT or "jaxlint" not in tok.string:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        reason = (m.group(2) or "").strip()
        line, col = tok.start
        standalone = not lines[line - 1][:col].strip() \
            if line <= len(lines) else False
        applies = line
        if standalone:
            # a standalone suppression covers the next CODE line, so the
            # justification may wrap over several comment lines
            applies = line + 1
            while applies <= len(lines) and (
                    not lines[applies - 1].strip()
                    or lines[applies - 1].lstrip().startswith("#")):
                applies += 1
        out.append(Suppression(rel, line, applies, rules, reason))
    return out


# ------------------------------------------------------------------- modules

@dataclass
class Module:
    path: Path
    rel: str
    source: str
    lines: List[str]
    tree: ast.Module
    suppressions: List[Suppression] = field(default_factory=list)
    dotted: str = ""     # "repro.core.rollout" when under a src root

    @classmethod
    def parse(cls, path: Path, rel: Optional[str] = None) -> "Module":
        src = path.read_text()
        rel = rel or str(path)
        tree = ast.parse(src, filename=rel)
        lines = src.splitlines()
        mod = cls(path=path, rel=rel, source=src, lines=lines, tree=tree,
                  suppressions=parse_suppressions(rel, src, lines),
                  dotted=_dotted_name(path))
        return mod


def _dotted_name(path: Path) -> str:
    """Best-effort module path ("repro.core.rollout") for import
    resolution: the parts after a ``src`` dir, else the stem."""
    parts = list(path.with_suffix("").parts)
    if "src" in parts:
        parts = parts[parts.index("src") + 1:]
    else:
        parts = parts[-1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)
