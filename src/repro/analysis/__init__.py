"""jaxlint — JAX-aware static analysis for this repo's bug classes.

Run it::

    python -m repro.analysis                  # lint src/repro
    python -m repro.analysis --catalog        # rule catalog
    python -m repro.analysis --format json benchmarks examples

Everything here is stdlib-only (``ast``, ``re``, ``json``, ``pathlib``) —
``scripts/check_deps.py`` asserts that importing this package never pulls
in jax or numpy, so linting costs milliseconds, not device init.

Why a bespoke linter: generic tools can't know that ``self.ref_params``
read inside a jitted update is a *frozen constant* (the PR-2 NFT bug) or
that eight ``float()`` calls per train step are eight device round-trips
(the PR-5 perf bug).  Those classes are mechanical given two repo-specific
facts the :class:`~repro.analysis.scopes.ScopeGraph` recovers from source:
which functions run under a trace (including through the
``distributed.jit_*`` wrapper layer), and which ``self.<attr>``\\ s each
class family mutates.

Adding a rule (registry-style, like every other repro component)::

    # src/repro/analysis/rules.py
    @register_rule
    class R008MyRule(Rule):
        id = "R008"                      # unique, R\\d{3}
        name = "my-rule"                 # kebab-case, shown in reports
        rationale = "one line: the bug class and why it matters"

        def check(self, module, graph):  # yield Finding objects
            for fi in graph.module_functions(module):
                if graph.is_traced(fi) and _looks_wrong(fi):
                    yield self.finding(module, fi.node, "explain the fix")

That's the whole integration: the driver discovers rules through the
registry, suppressions (``# jaxlint: disable=R008 — why``) and the
baseline work immediately, and ``--catalog`` picks up the rationale.
Add positive + negative fixtures in ``tests/test_analysis.py``.
"""
from repro.analysis.core import Finding, Module, Rule, Suppression, \
    all_rules, register_rule, rule_ids
from repro.analysis.scopes import ScopeGraph

# rule modules register on import
from repro.analysis import rules as _rules  # noqa: F401  (side effect)

__all__ = ["Finding", "Module", "Rule", "Suppression", "all_rules",
           "register_rule", "rule_ids", "ScopeGraph"]
