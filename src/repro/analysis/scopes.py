"""Traced-scope graph: which functions run under a JAX trace.

The graph answers one question for every function in the linted tree —
"can this code execute inside ``jax.jit`` / ``lax.scan`` / ``jax.checkpoint``
/ ``shard_map`` / ``pl.pallas_call`` (or ``vmap``/``grad``)?" — because the
bug classes the rules encode only exist (R003/R004) or only *don't* exist
(R002's host syncs) under a trace.

Construction, all stdlib ``ast``:

1. **Index** every function/method/lambda and class across the linted
   modules (nested defs are first-class nodes; classes record which methods
   assign which ``self.<attr>`` — R003's mutation map).
2. **Wrapper positions**: a repo function whose parameter flows into a
   tracing call (``def jit_update(fn, mesh): return jax.jit(fn,...)``)
   traces that argument position at every call site — this is how the
   ``distributed.jit_*`` indirection layer stays visible to the linter.
   Detection is *transitive* to a fixed point: a parameter forwarded into
   another wrapper's traced position (``def jit_sample(fn, ...): return
   _plan_jit(fn, ...)``) makes the forwarding function a wrapper too, and
   donation marks (``donate_argnums`` inside the innermost jit) propagate
   up the same chain, so R005's donated-buffer tracking follows the
   helper indirection.
3. **Roots**: every function passed to a tracing call / decorator
   (including ``functools.partial(jax.jit, ...)`` and wrapper call sites).
4. **Edges**: calls resolved by name — ``self.x`` binds within the class
   family (base + subclasses, so ``BaseTrainer`` reaching ``self.loss_fn``
   marks every trainer's override), module aliases bind to the imported
   module, anything else binds to every *arity-compatible* function of that
   name.  Deliberately over-approximate: a linter would rather walk into
   one function too many than miss a traced scope.
5. **Reachability**: BFS from the roots; ``graph.is_traced(fn)``.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Module

# tracing entry points, keyed by the trailing name of the callee; the value
# is the positional index of the function being traced
TRACERS: Dict[str, int] = {
    "jit": 0, "pjit": 0, "checkpoint": 0, "remat": 0, "scan": 0,
    "shard_map": 0, "pallas_call": 0, "vmap": 0, "pmap": 0, "grad": 0,
    "value_and_grad": 0, "custom_jvp": 0, "custom_vjp": 0,
}


def last_name(expr: ast.expr) -> Optional[str]:
    """Trailing identifier of a Name/Attribute chain (``jax.lax.scan`` ->
    ``"scan"``)."""
    if isinstance(expr, ast.Attribute):
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def root_name(expr: ast.expr) -> Optional[str]:
    """Leading identifier of a Name/Attribute chain (``jax.lax.scan`` ->
    ``"jax"``)."""
    while isinstance(expr, ast.Attribute):
        expr = expr.value
    return expr.id if isinstance(expr, ast.Name) else None


def tracer_fn_arg(call: ast.Call) -> Optional[ast.expr]:
    """The function expression a tracing call traces, or None.

    Handles ``jax.jit(f)``, ``lax.scan(body, ...)``, bare ``shard_map(f)``
    and ``jax.jit(functools.partial(f, ...))``."""
    name = last_name(call.func)
    if name not in TRACERS:
        return None
    pos = TRACERS[name]
    if len(call.args) <= pos:
        return None
    arg: ast.expr = call.args[pos]
    if isinstance(arg, ast.Call) and last_name(arg.func) == "partial" \
            and arg.args:
        arg = arg.args[0]
    return arg


def shallow_walk(node: ast.AST) -> Iterator[ast.AST]:
    """``ast.walk`` that does not descend into nested function/class
    definitions (they are separate graph nodes)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda,
                          ast.ClassDef)):
            continue
        stack.extend(ast.iter_child_nodes(n))


class FuncInfo:
    """One function/method/lambda definition."""

    __slots__ = ("node", "module", "name", "qualname", "class_name",
                 "parent", "is_method", "min_pos", "max_pos", "kw_names",
                 "has_varkw", "params")

    def __init__(self, node, module: Module, name: str, qualname: str,
                 class_name: Optional[str], parent: Optional["FuncInfo"]):
        self.node = node
        self.module = module
        self.name = name
        self.qualname = qualname
        self.class_name = class_name
        self.parent = parent
        a = node.args
        pos = list(a.posonlyargs) + list(a.args)
        self.params = [p.arg for p in pos]
        self.is_method = (class_name is not None and parent is None
                          and bool(pos) and pos[0].arg in ("self", "cls"))
        n_self = 1 if self.is_method else 0
        self.min_pos = max(0, len(pos) - len(a.defaults) - n_self)
        self.max_pos = None if a.vararg else len(pos) - n_self
        self.kw_names = {p.arg for p in pos[n_self:]} | \
                        {p.arg for p in a.kwonlyargs}
        self.has_varkw = a.kwarg is not None

    def accepts(self, npos: int, kwnames: Set[str], lenient: bool) -> bool:
        """Could a call with ``npos`` positional args + ``kwnames`` bind?"""
        if lenient:
            return True
        if self.max_pos is not None and npos > self.max_pos:
            return False
        if not self.has_varkw and not (kwnames <= self.kw_names):
            return False
        if npos + len(kwnames) < self.min_pos:
            return False
        return True

    def __repr__(self):
        return f"<FuncInfo {self.module.rel}:{self.qualname}>"


class ClassInfo:
    __slots__ = ("node", "module", "name", "bases", "methods",
                 "attr_writers")

    def __init__(self, node: ast.ClassDef, module: Module):
        self.node = node
        self.module = module
        self.name = node.name
        self.bases = [last_name(b) for b in node.bases
                      if last_name(b) is not None]
        self.methods: Dict[str, FuncInfo] = {}
        # attr -> {method names that assign self.attr}
        self.attr_writers: Dict[str, Set[str]] = {}


class ScopeGraph:
    def __init__(self, modules: Sequence[Module]):
        self.modules = list(modules)
        self.functions: Dict[int, FuncInfo] = {}        # id(node) -> info
        self.by_name: Dict[str, List[FuncInfo]] = {}
        self.classes: Dict[str, List[ClassInfo]] = {}   # name -> defs
        self.module_by_dotted: Dict[str, Module] = {}
        self.imports: Dict[str, Dict[str, str]] = {}    # rel -> alias->dotted
        self.module_funcs: Dict[str, Dict[str, FuncInfo]] = {}
        # wrapper name -> positions whose argument gets traced
        self.wrapper_positions: Dict[int, Set[int]] = {}
        # wrapper funcs whose internal jit passes donate_argnums
        self.wrapper_donates: Dict[int, Set[int]] = {}
        self.edges: Dict[int, Set[int]] = {}
        self.roots: Set[int] = set()
        self.traced: Set[int] = set()
        self._family_cache: Dict[str, Set[str]] = {}
        self._bound_cache: Dict[int, Set[str]] = {}
        self._nested_cache: Dict[int, Dict[str, FuncInfo]] = {}
        self._resolve_memo: Dict[int, List[FuncInfo]] = {}

        for mod in self.modules:
            self.module_by_dotted[mod.dotted] = mod
            self._index_module(mod)
        self._find_wrappers()
        for mod in self.modules:
            self._roots_and_edges(mod)
        self._bfs()

    # ------------------------------------------------------------- indexing
    def _index_module(self, mod: Module) -> None:
        imports: Dict[str, str] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    imports[a.asname or a.name.split(".")[0]] = a.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    imports[a.asname or a.name] = f"{node.module}.{a.name}"
        self.imports[mod.rel] = imports
        self.module_funcs[mod.rel] = {}

        def visit(node, cls: Optional[ClassInfo], fn: Optional[FuncInfo],
                  qual: str):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, ast.ClassDef):
                    ci = ClassInfo(child, mod)
                    self.classes.setdefault(ci.name, []).append(ci)
                    visit(child, ci, None, f"{qual}{child.name}.")
                elif isinstance(child, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    fi = FuncInfo(child, mod, child.name,
                                  f"{qual}{child.name}",
                                  cls.name if cls else
                                  (fn.class_name if fn else None), fn)
                    self._add_func(fi, mod, cls, fn)
                    visit(child, None, fi, f"{qual}{child.name}.")
                elif isinstance(child, ast.Lambda):
                    fi = FuncInfo(child, mod, "<lambda>",
                                  f"{qual}<lambda>",
                                  fn.class_name if fn else
                                  (cls.name if cls else None), fn)
                    self.functions[id(child)] = fi
                    visit(child, None, fi, f"{qual}<lambda>.")
                else:
                    visit(child, cls, fn, qual)

        visit(mod.tree, None, None, "")

        # self.<attr> mutation map, per class
        for cis in self.classes.values():
            for ci in cis:
                if ci.module is not mod:
                    continue
                for mname, mi in ci.methods.items():
                    for n in ast.walk(mi.node):
                        tgt = None
                        if isinstance(n, (ast.Assign, ast.AugAssign,
                                          ast.AnnAssign)):
                            tgts = (n.targets if isinstance(n, ast.Assign)
                                    else [n.target])
                            for t in tgts:
                                for e in ast.walk(t):
                                    if (isinstance(e, ast.Attribute)
                                            and isinstance(e.value, ast.Name)
                                            and e.value.id == "self"):
                                        tgt = e.attr
                                        ci.attr_writers.setdefault(
                                            tgt, set()).add(mname)

    def _add_func(self, fi: FuncInfo, mod: Module, cls: Optional[ClassInfo],
                  parent: Optional[FuncInfo]) -> None:
        self.functions[id(fi.node)] = fi
        self.by_name.setdefault(fi.name, []).append(fi)
        if cls is not None and parent is None:
            cls.methods[fi.name] = fi
        if cls is None and parent is None:
            self.module_funcs[mod.rel][fi.name] = fi

    # ------------------------------------------------------------- wrappers
    def _find_wrappers(self) -> None:
        """Functions whose parameter flows into a tracing call: calling
        them traces that argument (the ``distributed.jit_*`` layer).

        Runs to a fixed point so the property is transitive: a parameter
        forwarded into an already-known wrapper's traced position makes
        the forwarding function a wrapper at that position too, and the
        callee's donation marks are inherited (donated positions index the
        *wrapped function's* arguments, so they are layout-stable across
        forwarding layers)."""
        for fi in list(self.functions.values()):
            if not isinstance(fi.node, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                continue
            params = fi.params[1:] if fi.is_method else fi.params
            for n in shallow_walk(fi.node):
                if not isinstance(n, ast.Call):
                    continue
                arg = tracer_fn_arg(n)
                if isinstance(arg, ast.Name) and arg.id in params:
                    idx = params.index(arg.id)
                    self.wrapper_positions.setdefault(id(fi.node),
                                                      set()).add(idx)
                    if (last_name(n.func) in ("jit", "pjit") and any(
                            kw.arg == "donate_argnums" for kw in n.keywords)):
                        self.wrapper_donates.setdefault(
                            id(fi.node), set()).update(
                            _donated_positions(n))
        # transitive closure over wrapper-to-wrapper forwarding
        changed = True
        while changed:
            changed = False
            for fi in list(self.functions.values()):
                if not isinstance(fi.node, (ast.FunctionDef,
                                            ast.AsyncFunctionDef)):
                    continue
                params = fi.params[1:] if fi.is_method else fi.params
                for n in shallow_walk(fi.node):
                    if not isinstance(n, ast.Call):
                        continue
                    for callee in self.resolve_call(n, fi.module, fi):
                        if self._inherit_wrapper(fi, params, n, callee):
                            changed = True

    def _inherit_wrapper(self, fi: FuncInfo, params: List[str],
                         call: ast.Call, callee: FuncInfo) -> bool:
        """Propagate ``callee``'s wrapper marks onto ``fi`` when one of
        ``fi``'s parameters is forwarded positionally into a traced
        position of ``callee``.  Returns True when anything new landed."""
        positions = self.wrapper_positions.get(id(callee.node))
        if not positions or callee.node is fi.node:
            return False
        changed = False
        for idx in positions:
            if idx >= len(call.args):
                continue
            arg = call.args[idx]
            if not (isinstance(arg, ast.Name) and arg.id in params):
                continue
            p = params.index(arg.id)
            wp = self.wrapper_positions.setdefault(id(fi.node), set())
            if p not in wp:
                wp.add(p)
                changed = True
            donated = self.wrapper_donates.get(id(callee.node))
            if donated:
                wd = self.wrapper_donates.setdefault(id(fi.node), set())
                if not donated <= wd:
                    wd |= donated
                    changed = True
        return changed

    # ------------------------------------------------------ class families
    def family(self, class_name: str) -> Set[str]:
        """Names connected to ``class_name`` through base-class edges (both
        directions): a base reaching ``self.x`` may bind any subclass
        override and vice versa."""
        if class_name in self._family_cache:
            return self._family_cache[class_name]
        # build undirected adjacency lazily over all classes
        adj: Dict[str, Set[str]] = {}
        for name, cis in self.classes.items():
            adj.setdefault(name, set())
            for ci in cis:
                for b in ci.bases:
                    if b in self.classes:
                        adj[name].add(b)
                        adj.setdefault(b, set()).add(name)
        seen = {class_name}
        frontier = [class_name]
        while frontier:
            cur = frontier.pop()
            for nxt in adj.get(cur, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    frontier.append(nxt)
        self._family_cache[class_name] = seen
        return seen

    def family_methods(self, class_name: str, method: str) -> List[FuncInfo]:
        out = []
        for cname in self.family(class_name):
            for ci in self.classes.get(cname, []):
                if method in ci.methods:
                    out.append(ci.methods[method])
        return out

    def family_attr_writers(self, class_name: str, attr: str) -> Set[str]:
        out: Set[str] = set()
        for cname in self.family(class_name):
            for ci in self.classes.get(cname, []):
                out |= ci.attr_writers.get(attr, set())
        return out

    # ----------------------------------------------------------- resolution
    def resolve_callable(self, expr: ast.expr, mod: Module,
                         encl: Optional[FuncInfo]) -> List[FuncInfo]:
        """Function definitions a function-valued expression may denote."""
        if isinstance(expr, ast.Lambda):
            fi = self.functions.get(id(expr))
            return [fi] if fi else []
        if isinstance(expr, ast.Name):
            # enclosing nested defs, then module level, then global
            f = encl
            while f is not None:
                hit = self._nested_defs(f).get(expr.id)
                if hit is not None:
                    return [hit]
                # a plain local binding (param / assignment) shadows
                # everything: the value is a runtime object the linter
                # can't name — resolving it globally would be noise
                if expr.id in self._bound_names(f):
                    return []
                f = f.parent
            if expr.id in self.module_funcs.get(mod.rel, {}):
                return [self.module_funcs[mod.rel][expr.id]]
            dotted = self.imports.get(mod.rel, {}).get(expr.id)
            if dotted:
                hit = self._resolve_dotted(dotted)
                if hit:
                    return hit
            if expr.id in self._module_assigned(mod):
                return []
            return self.by_name.get(expr.id, [])
        if isinstance(expr, ast.Attribute):
            name = expr.attr
            recv = expr.value
            if isinstance(recv, ast.Name) and recv.id in ("self", "cls"):
                cls_name = _enclosing_class(encl)
                if cls_name:
                    hits = self.family_methods(cls_name, name)
                    if hits:
                        return hits
                return [fi for fi in self.by_name.get(name, [])
                        if fi.is_method]
            if isinstance(recv, ast.Name):
                alias = self.imports.get(mod.rel, {}).get(recv.id)
                if alias and alias in self.module_by_dotted:
                    target = self.module_by_dotted[alias]
                    hit = self.module_funcs.get(target.rel, {}).get(name)
                    if hit:
                        return [hit]
            # `<recv>.get(...)` etc. is almost always a container op, not
            # a repo method — the global fallback would wire dict lookups
            # in traced code to every class that happens to define `get`
            if name in _CONTAINER_PROTOCOL:
                return []
            return self.by_name.get(name, [])
        return []

    def _resolve_dotted(self, dotted: str) -> List[FuncInfo]:
        if dotted in self.module_by_dotted:
            return []
        mod_path, _, sym = dotted.rpartition(".")
        target = self.module_by_dotted.get(mod_path)
        if target:
            hit = self.module_funcs.get(target.rel, {}).get(sym)
            if hit:
                return [hit]
        return []

    def resolve_call(self, call: ast.Call, mod: Module,
                     encl: Optional[FuncInfo]) -> List[FuncInfo]:
        """Call targets, arity-filtered (a 5-arg ``scheduler.step(...)``
        never binds a 2-arg ``Trainer.step``).  Memoized per call node —
        several rules resolve the same calls."""
        memo = self._resolve_memo.get(id(call))
        if memo is not None:
            return memo
        cands = self.resolve_callable(call.func, mod, encl)
        lenient = (any(isinstance(a, ast.Starred) for a in call.args)
                   or any(kw.arg is None for kw in call.keywords))
        npos = len(call.args)
        kwnames = {kw.arg for kw in call.keywords if kw.arg}
        out = [fi for fi in cands if fi.accepts(npos, kwnames, lenient)]
        self._resolve_memo[id(call)] = out
        return out

    # -------------------------------------------------------- roots + edges
    def _roots_and_edges(self, mod: Module) -> None:
        def handle_body(owner: Optional[FuncInfo], body_owner_node):
            for n in shallow_walk(body_owner_node):
                if not isinstance(n, ast.Call):
                    continue
                # (a) direct tracing call
                arg = tracer_fn_arg(n)
                if arg is not None:
                    for fi in self.resolve_callable(arg, mod, owner):
                        self.roots.add(id(fi.node))
                # (b) wrapper call site
                for fi in self.resolve_call(n, mod, owner):
                    positions = self.wrapper_positions.get(id(fi.node))
                    if positions:
                        for idx in positions:
                            if idx < len(n.args):
                                for tfi in self.resolve_callable(
                                        n.args[idx], mod, owner):
                                    self.roots.add(id(tfi.node))
                # (c) plain call edge
                if owner is not None:
                    tgts = self.resolve_call(n, mod, owner)
                    if tgts:
                        self.edges.setdefault(id(owner.node), set()).update(
                            id(t.node) for t in tgts)

        for fid, fi in self.functions.items():
            if fi.module is not mod:
                continue
            # traced decorators
            node = fi.node
            for dec in getattr(node, "decorator_list", []):
                target = dec.func if isinstance(dec, ast.Call) else dec
                tl = last_name(target)
                if tl in TRACERS:
                    self.roots.add(fid)
                elif tl == "partial" and isinstance(dec, ast.Call) \
                        and dec.args and last_name(dec.args[0]) in TRACERS:
                    self.roots.add(fid)
            handle_body(fi, node)
        handle_body(None, mod.tree)       # module-level tracing calls

    def _bfs(self) -> None:
        frontier = list(self.roots)
        self.traced = set(self.roots)
        while frontier:
            cur = frontier.pop()
            for nxt in self.edges.get(cur, ()):
                if nxt not in self.traced:
                    self.traced.add(nxt)
                    frontier.append(nxt)

    def _nested_defs(self, fi: FuncInfo) -> Dict[str, FuncInfo]:
        cached = self._nested_cache.get(id(fi.node))
        if cached is None:
            cached = {
                n.name: self.functions[id(n)]
                for n in shallow_walk(fi.node)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
            self._nested_cache[id(fi.node)] = cached
        return cached

    def _bound_names(self, fi: FuncInfo) -> Set[str]:
        """Names bound inside ``fi`` by parameters or plain statements
        (assignments, for/with/except targets) — NOT nested defs."""
        cached = self._bound_cache.get(id(fi.node))
        if cached is not None:
            return cached
        node = fi.node
        a = node.args
        names: Set[str] = set(fi.params)
        names.update(p.arg for p in a.kwonlyargs)
        if a.vararg:
            names.add(a.vararg.arg)
        if a.kwarg:
            names.add(a.kwarg.arg)
        names |= _stmt_bound_names(node)
        self._bound_cache[id(fi.node)] = names
        return names

    def _module_assigned(self, mod: Module) -> Set[str]:
        cached = self._bound_cache.get(id(mod.tree))
        if cached is not None:
            return cached
        names = _stmt_bound_names(mod.tree)
        self._bound_cache[id(mod.tree)] = names
        return names

    # ------------------------------------------------------------- queries
    def is_traced(self, fi: FuncInfo) -> bool:
        return id(fi.node) in self.traced

    def module_functions(self, mod: Module) -> List[FuncInfo]:
        return [fi for fi in self.functions.values() if fi.module is mod]


# attribute names resolved only against self/cls or module aliases, never
# through the global by-name fallback (dict/list/set protocol)
_CONTAINER_PROTOCOL = {
    "get", "items", "keys", "values", "pop", "popitem", "setdefault",
    "update", "append", "extend", "insert", "remove", "add", "discard",
    "clear", "copy", "index", "count", "sort", "reverse", "join",
    "move_to_end",
}


def _stmt_bound_names(node: ast.AST) -> Set[str]:
    names: Set[str] = set()

    def targets(t: ast.expr) -> Iterator[str]:
        for e in ast.walk(t):
            if isinstance(e, ast.Name) and isinstance(e.ctx, ast.Store):
                yield e.id

    for n in shallow_walk(node):
        if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            for t in (n.targets if isinstance(n, ast.Assign)
                      else [n.target]):
                names.update(targets(t))
        elif isinstance(n, (ast.For, ast.AsyncFor)):
            names.update(targets(n.target))
        elif isinstance(n, ast.comprehension):
            names.update(targets(n.target))
        elif isinstance(n, ast.withitem) and n.optional_vars is not None:
            names.update(targets(n.optional_vars))
        elif isinstance(n, ast.ExceptHandler) and n.name:
            names.add(n.name)
        elif isinstance(n, ast.NamedExpr):
            names.update(targets(n.target))
    return names


def _enclosing_class(fi: Optional[FuncInfo]) -> Optional[str]:
    while fi is not None:
        if fi.class_name:
            return fi.class_name
        fi = fi.parent
    return None


def _donated_positions(jit_call: ast.Call) -> Set[int]:
    """Literal donate_argnums positions, or {0} when the value is computed
    (the repo convention donates the leading state buffer)."""
    for kw in jit_call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Tuple):
                out = {e.value for e in v.elts
                       if isinstance(e, ast.Constant)
                       and isinstance(e.value, int)}
                if out:
                    return out
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return {v.value}
            return {0}
    return set()
