"""FlowAdapter — the paper's ``BaseAdapter`` model operation: wrap *any*
backbone in the zoo as a flow-matching velocity field ``v_θ(x_t, c, t)``.

Latent tokens (the "image"/"video" latent of the paper's Flux/WAN pipelines)
are projected into the backbone width, prefixed with projected condition
embeddings (from the preprocessing cache) and a timestep token, run through
the backbone, and projected back to latent space.

* ``dit`` family backbones run bidirectionally with adaLN-zero conditioning
  (exactly a FLUX-style DiT).
* LM-family backbones (all 10 assigned archs) run causally with the
  condition prefix — causal DiT semantics.  SSM/hybrid backbones are causal
  by construction, which is why the technique stays applicable to them
  (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import registry
from repro.config import ArchConfig, FlowRLConfig
from repro.models import layers
from repro.models.backbone import Backbone
from repro.models.params import P

F32 = jnp.float32


@registry.register("adapter", "flow")
class FlowAdapter:
    """Velocity-field adapter over a Backbone."""

    def __init__(self, cfg: ArchConfig, flow_cfg: FlowRLConfig,
                 cond_dim: int = 512, policy_dtype=None):
        self.cfg = cfg
        self.flow_cfg = flow_cfg
        self.cond_dim = cond_dim
        self.backbone = Backbone(cfg)
        # explicit activation compute dtype (PerfConfig.policy_dtype);
        # None inherits the parameter storage dtype — the historical
        # behaviour, kept as the bit-identical default
        self.policy_dtype = policy_dtype

    # ------------------------------------------------------------------ spec
    def spec(self) -> Dict:
        d = self.cfg.d_model
        ld = self.flow_cfg.latent_dim
        s = {
            "backbone": self.backbone.spec(),
            "latent_in": P((ld, d), ("latent", "embed")),
            "latent_out": P((d, ld), ("embed", "latent"), "small"),
            "time_w1": P((d, d), ("embed", "time")),
            "time_w2": P((d, d), ("time", "embed")),
            "cond_proj": P((self.cond_dim, d), ("cond", "embed")),
        }
        return s

    # -------------------------------------------------------------- velocity
    def velocity(self, params: Dict, x_t: jax.Array, t: jax.Array,
                 cond: jax.Array, *, remat: bool = False) -> jax.Array:
        """x_t: (B, Lt, latent_dim); t: (B,) in [0,1]; cond: (B, Lc, cond_dim).

        Returns v: (B, Lt, latent_dim) — always float32 (the log-prob side
        of the mixed-precision policy).  ``remat=True`` threads the
        backbone's per-layer block checkpointing through the forward
        (``PerfConfig.remat="block"`` — f32-rounding-equal, not exact).
        """
        cfg = self.cfg
        B, Lt, ld = x_t.shape
        dtype = self.policy_dtype or params["latent_in"].dtype

        h_lat = jnp.einsum("bld,de->ble", x_t.astype(dtype),
                           params["latent_in"],
                           preferred_element_type=F32).astype(dtype)
        h_cond = jnp.einsum("blc,cd->bld", cond.astype(dtype),
                            params["cond_proj"],
                            preferred_element_type=F32).astype(dtype)
        t_feat = layers.timestep_embedding(t, cfg.d_model).astype(dtype)
        t_emb = jnp.einsum(
            "bd,de->be",
            jax.nn.silu(jnp.einsum("bd,de->be", t_feat, params["time_w1"],
                                   preferred_element_type=F32)).astype(dtype),
            params["time_w2"], preferred_element_type=F32).astype(dtype)

        if cfg.family == "dit":
            # bidirectional DiT: condition prefix + adaLN time modulation
            x = jnp.concatenate([h_cond, h_lat], axis=1)
            hidden, _, _ = self.backbone.forward_embeds(
                params["backbone"], x, causal=False, cond=t_emb, remat=remat)
        else:
            # causal DiT: [cond prefix; time token; latent tokens]
            x = jnp.concatenate([h_cond, t_emb[:, None, :], h_lat], axis=1)
            hidden, _, _ = self.backbone.forward_embeds(
                params["backbone"], x, causal=True, remat=remat)
        h_out = hidden[:, -Lt:]
        v = jnp.einsum("bld,dk->blk", h_out, params["latent_out"],
                       preferred_element_type=F32)
        return v.astype(F32)

    # ------------------------------------------------------------------ misc
    def init_latent(self, key: jax.Array, batch: int) -> jax.Array:
        return jax.random.normal(
            key, (batch, self.flow_cfg.latent_tokens, self.flow_cfg.latent_dim),
            F32)
