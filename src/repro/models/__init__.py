from repro.models.backbone import Backbone
from repro.models import tasks

__all__ = ["Backbone", "tasks"]
