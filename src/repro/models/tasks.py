"""Step functions for the LM role of every architecture: train (next-token),
prefill, and one-token decode.  These are what the multi-pod dry-run lowers
for the 40 (arch × shape) pairs; the flow-RL steps (the paper's pipeline)
live in ``repro.core.trainers`` and reuse the same backbones.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim, registry
from repro.config import ArchConfig, InputShape, OptimConfig, RunConfig
from repro.models import params as params_lib
from repro.models.backbone import Backbone
from repro.models.layers import chunked_ce_loss

F32 = jnp.float32


class TrainState(NamedTuple):
    params: Any
    opt: optim.AdamWState


# ---------------------------------------------------------------------------
# Shape policy
# ---------------------------------------------------------------------------

def effective_window(cfg: ArchConfig, shape: InputShape) -> int:
    """Sliding-window policy: full attention everywhere except long_500k,
    where attention archs switch to their sliding-window variant (the
    sub-quadratic requirement); SSM archs have no attention at all."""
    if cfg.family == "ssm":
        return 0
    if shape.seq_len > 65536 and shape.kind in ("decode", "prefill"):
        return cfg.window or 8192
    return 0


def effective_cache_len(cfg: ArchConfig, shape: InputShape) -> int:
    w = effective_window(cfg, shape)
    return min(shape.seq_len, w) if w else shape.seq_len


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.bfloat16):
    return params_lib.init(Backbone(cfg).spec(), key, dtype)


def init_caches(cfg: ArchConfig, batch: int, cache_len: int,
                dtype=jnp.bfloat16):
    model = Backbone(cfg)
    spec = model.cache_specs(batch, cache_len)
    return jax.tree.map(
        lambda sa: jnp.zeros(sa[0], dtype), spec,
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
        and isinstance(x[0], tuple))


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, opt_cfg: OptimConfig, *,
                    window: int = 0, remat: bool = True):
    model = Backbone(cfg)
    lr_fn = optim.make_schedule(opt_cfg)
    # same registry-selected optimizer as the RL trainers, so one
    # OptimConfig means the same thing on both training paths.  NOTE:
    # callers construct the matching state (TrainState.opt) themselves —
    # a newly registered optimizer must keep the AdamWState (step, mu, nu)
    # layout or also take over the init sites (tests, launch/specs).
    optimizer = registry.build("optimizer", opt_cfg.optimizer)
    n_pre = model.n_prefix

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        def loss_fn(p):
            x = model.embed_inputs(p, batch["tokens"],
                                   batch.get("prefix_embed"))
            hidden, _, aux = model.forward_embeds(
                p, x, causal=True, window=window, remat=remat)
            if n_pre:
                hidden = hidden[:, n_pre:]
            ce = chunked_ce_loss(hidden, model.head_matrix(p),
                                 batch["labels"])
            total = ce + sum(aux.values()) if aux else ce
            return total, (ce, aux)

        (total, (ce, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state.params)
        grads, gnorm = optim.clip_by_global_norm(grads, opt_cfg.grad_clip)
        lr = lr_fn(state.opt.step)
        new_p, new_opt = optimizer.update(state.params, grads, state.opt,
                                          opt_cfg, lr)
        metrics = {"loss": total, "ce": ce, "grad_norm": gnorm, "lr": lr}
        metrics.update(aux)
        return TrainState(new_p, new_opt), metrics

    return train_step


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------

def make_prefill_step(cfg: ArchConfig, *, window: int = 0):
    model = Backbone(cfg)

    def prefill_step(p, batch: Dict[str, jax.Array]):
        x = model.embed_inputs(p, batch["tokens"], batch.get("prefix_embed"))
        hidden, caches, _ = model.forward_embeds(
            p, x, causal=True, window=window, return_caches=True)
        last_logits = model.logits(p, hidden[:, -1])
        return last_logits, caches

    return prefill_step


def make_decode_step(cfg: ArchConfig, *, window: int = 0):
    model = Backbone(cfg)

    def decode_step(p, caches, token: jax.Array, pos: jax.Array):
        """token: (B, 1) int32; pos: scalar int32 absolute position."""
        x = model.embed_inputs(p, token)
        hidden, caches = model.decode_embeds(p, x, caches, pos, window=window)
        logits = model.logits(p, hidden[:, -1])
        return logits, caches

    return decode_step


# ---------------------------------------------------------------------------
# Synthetic batches (smoke tests / examples)
# ---------------------------------------------------------------------------

def synthetic_batch(cfg: ArchConfig, batch: int, seq: int, key: jax.Array
                    ) -> Dict[str, jax.Array]:
    kt, kp = jax.random.split(key)
    out = {
        "tokens": jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size,
                                     jnp.int32),
    }
    out["labels"] = jnp.roll(out["tokens"], -1, axis=1)
    if cfg.frontend.kind != "none":
        out["prefix_embed"] = jax.random.normal(
            kp, (batch, cfg.frontend.n_tokens, cfg.frontend.embed_dim),
            jnp.float32).astype(jnp.bfloat16)
    return out
