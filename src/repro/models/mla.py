"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Keys/values are compressed into a rank-``kv_lora_rank`` latent ``c_kv`` plus a
small shared RoPE key.  Train/prefill expands the latent into per-head K/V;
decode uses the *absorbed* formulation (W_uk folded into the query, W_uv into
the output), so the KV cache is only ``(T, kv_lora_rank + rope_dim)`` per
sequence — the memory win that defines MLA.

Sharding: the up-projections ``w_uq``/``w_uk``/``w_uv`` and the output
projection ``wo`` carry the ``"heads"`` logical axis in their specs, so
under ``dist.model_parallel>1`` the :class:`~repro.distributed.PartitionPlan`
shards them head-parallel (``MODEL_SHARDABLE`` priority); the small
latent down-projections and norms stay replicated or fall back to embed
(FSDP) sharding.  Declared here via :class:`repro.models.params.P` — the
distributed layer never names modules.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import layers
from repro.models.params import P

F32 = layers.F32


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, T, R)   compressed KV latent
    k_rope: jax.Array  # (B, T, Dr)  shared rope key


def spec(cfg: ArchConfig) -> Dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": P((d, m.q_lora_rank), ("embed", "q_lora")),
        "q_norm": P((m.q_lora_rank,), ("norm",), "ones"),
        "w_uq": P((m.q_lora_rank, H, qk), ("q_lora", "heads", "head_dim")),
        "w_dkv": P((d, m.kv_lora_rank + m.qk_rope_head_dim),
                   ("embed", "kv_lora")),
        "kv_norm": P((m.kv_lora_rank,), ("norm",), "ones"),
        "w_uk": P((m.kv_lora_rank, H, m.qk_nope_head_dim),
                  ("kv_lora", "heads", "head_dim")),
        "w_uv": P((m.kv_lora_rank, H, m.v_head_dim),
                  ("kv_lora", "heads", "head_dim")),
        "wo": P((H, m.v_head_dim, d), ("heads", "head_dim", "embed_r")),
    }


def _q_proj(p: Dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array
            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (q_nope (B,S,H,Dn), q_rope (B,S,H,Dr))."""
    m = cfg.mla
    cq = layers.rmsnorm(p["q_norm"],
                        jnp.einsum("bsd,dr->bsr", x, p["w_dq"],
                                   preferred_element_type=F32).astype(x.dtype),
                        cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"],
                   preferred_element_type=F32).astype(x.dtype)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = layers.apply_rope(q[..., m.qk_nope_head_dim:], positions,
                               cfg.rope_theta)
    return q_nope, q_rope


def _kv_latent(p: Dict, cfg: ArchConfig, x: jax.Array, positions: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Returns (c_kv (B,S,R), k_rope (B,S,Dr))."""
    m = cfg.mla
    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"],
                     preferred_element_type=F32).astype(x.dtype)
    c_kv = layers.rmsnorm(p["kv_norm"], dkv[..., :m.kv_lora_rank],
                          cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:]
    k_rope = layers.apply_rope(k_rope[:, :, None, :], positions,
                               cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def apply_full(p: Dict, cfg: ArchConfig, x: jax.Array, *,
               causal: bool = True, window: int = 0,
               positions: Optional[jax.Array] = None,
               return_cache: bool = False
               ) -> Tuple[jax.Array, Optional[MLACache]]:
    """Train/prefill path: expand the latent into per-head K/V."""
    m = cfg.mla
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    q_nope, q_rope = _q_proj(p, cfg, x, positions)
    c_kv, k_rope = _kv_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"],
                        preferred_element_type=F32).astype(x.dtype)
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"],
                   preferred_element_type=F32).astype(x.dtype)
    # concat nope+rope so we can reuse the shared attention math; the rope key
    # is broadcast across heads
    H = cfg.n_heads
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :],
                                (B, S, H, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    o = layers.attention_chunked(q, k, v, causal=causal, window=window,
                                 q_positions=positions, k_positions=positions,
                                 scale=scale)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=F32).astype(x.dtype)
    cache = MLACache(c_kv, k_rope) if return_cache else None
    return out, cache


def apply_decode(p: Dict, cfg: ArchConfig, x: jax.Array, cache: MLACache,
                 pos: jax.Array, *, window: int = 0
                 ) -> Tuple[jax.Array, MLACache]:
    """Absorbed decode: attention runs in the rank-R latent space.

    scores_h = q_nope_h · W_uk_h · c_kv  +  q_rope_h · k_rope
    out_h    = (softmax · c_kv) · W_uv_h
    """
    m = cfg.mla
    B = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _q_proj(p, cfg, x, positions)       # (B,1,H,*)
    c_new, kr_new = _kv_latent(p, cfg, x, positions)     # (B,1,R),(B,1,Dr)
    # attend over the FULL cache plus the new entry (T+1)…
    c_kv = jnp.concatenate([cache.c_kv, c_new], axis=1)
    k_rope = jnp.concatenate([cache.k_rope, kr_new], axis=1)

    # absorb W_uk into the query: (B,H,R)
    q_abs = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["w_uk"],
                       preferred_element_type=F32).astype(x.dtype)
    s_nope = jnp.einsum("bhr,btr->bht", q_abs, c_kv,
                        preferred_element_type=F32)
    s_rope = jnp.einsum("bhk,btk->bht", q_rope[:, 0], k_rope,
                        preferred_element_type=F32)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    w = jax.nn.softmax((s_nope + s_rope) * scale, axis=-1).astype(x.dtype)
    o_lat = jnp.einsum("bht,btr->bhr", w, c_kv,
                       preferred_element_type=F32).astype(x.dtype)
    # absorb W_uv on the way out
    o = jnp.einsum("bhr,rhk->bhk", o_lat, p["w_uv"],
                   preferred_element_type=F32).astype(x.dtype)
    out = jnp.einsum("bhk,hkd->bd", o, p["wo"],
                     preferred_element_type=F32)[:, None, :].astype(x.dtype)
    # …then roll the ring buffer (oldest entry out, shape stays static)
    return out, MLACache(c_kv[:, 1:], k_rope[:, 1:])


def init_cache_shapes(cfg: ArchConfig, batch: int, cache_len: int):
    m = cfg.mla
    return {
        "c_kv": ((batch, cache_len, m.kv_lora_rank),
                 ("batch", "cache_seq", "kv_lora")),
        "k_rope": ((batch, cache_len, m.qk_rope_head_dim),
                   ("batch", "cache_seq", "head_dim")),
    }
