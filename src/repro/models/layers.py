"""Shared neural-net layers (pure functions over param pytrees).

Numerics policy: activations in ``cfg`` dtype (bf16 by default); norms,
softmax, and matmul accumulation in f32 (``preferred_element_type``).
REPRO_BF16_REDUCE=1 (perf knob, §Perf iteration): output projections whose
contraction dim is model-sharded (wo, w_down) accumulate in bf16 instead of
f32, halving the bytes of the partial-sum all-reduce the SPMD partitioner
inserts.  Per-device MXU accumulation quality is unchanged on TPU (the MXU
accumulates f32 internally per dot); only the cross-shard summation is bf16 —
the standard Megatron-style trade.
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.params import P

F32 = jnp.float32


def reduce_dtype():
    """Accumulation dtype for model-sharded (partial-summed) contractions."""
    return jnp.bfloat16 if os.environ.get("REPRO_BF16_REDUCE") else F32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int) -> P:
    return P((d,), ("norm",), "ones")


def rmsnorm(w: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * w.astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=F32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with D even; positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                    # (D/2,)
    angles = positions.astype(F32)[..., None] * freqs     # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                   # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(F32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_spec(d: int, f: int) -> dict:
    return {
        "w_gate": P((d, f), ("embed", "mlp")),
        "w_up": P((d, f), ("embed", "mlp")),
        "w_down": P((f, d), ("mlp", "embed_r")),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"],
                   preferred_element_type=F32)
    u = jnp.einsum("...d,df->...f", x, p["w_up"],
                   preferred_element_type=F32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["w_down"],
                      preferred_element_type=reduce_dtype()).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention math (reference/jnp path; Pallas kernel path lives in
# repro.kernels and is dispatched by repro.kernels.ops on TPU)
# ---------------------------------------------------------------------------

NEG_INF = -0.7 * jnp.finfo(jnp.float32).max


def _mask_bias(q_pos: jax.Array, k_pos: jax.Array, causal: bool,
               window: int) -> jax.Array:
    """(Sq, Sk) additive bias. window>0 => sliding-window of that width."""
    dq = q_pos[:, None]
    dk = k_pos[None, :]
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= dk <= dq
    if window > 0:
        ok &= dk > dq - window
    return jnp.where(ok, 0.0, NEG_INF)


def attention_chunked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int = 0,
                      q_positions: Optional[jax.Array] = None,
                      k_positions: Optional[jax.Array] = None,
                      chunk_q: int = 1024,
                      scale: Optional[float] = None) -> jax.Array:
    """Memory-bounded attention: lax.scan over query chunks.

    q: (B, Sq, H, D); k, v: (B, Sk, K, D) with H % K == 0 (GQA).
    Peak scores memory = B * H * chunk_q * Sk * 4 bytes instead of Sq * Sk.
    """
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    if q_positions is None:
        q_positions = jnp.arange(Sq, dtype=jnp.int32)
    if k_positions is None:
        k_positions = jnp.arange(Sk, dtype=jnp.int32)

    qg = q.reshape(B, Sq, K, G, D)

    def one_chunk(q_chunk: jax.Array, qpos_chunk: jax.Array) -> jax.Array:
        # q_chunk: (B, C, K, G, D)
        s = jnp.einsum("bckgd,btkd->bckgt", q_chunk, k,
                       preferred_element_type=F32) * scale
        s = s + _mask_bias(qpos_chunk, k_positions, causal, window)[
            None, :, None, None, :]
        p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bckgt,btkd->bckgd", p, v,
                          preferred_element_type=F32).astype(q.dtype)

    if Sq <= chunk_q:
        out = one_chunk(qg, q_positions)
    else:
        n = Sq // chunk_q
        rem = Sq - n * chunk_q
        qs = qg[:, :n * chunk_q].reshape(B, n, chunk_q, K, G, D)
        ps = q_positions[:n * chunk_q].reshape(n, chunk_q)
        # scan over chunks (compile-time O(1) in Sq)
        outs = jax.lax.map(lambda args: one_chunk(*args),
                           (qs.transpose(1, 0, 2, 3, 4, 5), ps))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, n * chunk_q, K, G, Dv)
        if rem:
            tail = one_chunk(qg[:, n * chunk_q:], q_positions[n * chunk_q:])
            out = jnp.concatenate([out, tail], axis=1)
    return out.reshape(B, Sq, H, Dv)


def attention_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     k_new: jax.Array, v_new: jax.Array, *,
                     window: int = 0, cache_len: Optional[int] = None,
                     scale: Optional[float] = None) -> jax.Array:
    """One-token decode attention.

    q: (B, 1, H, D); caches: (B, T, K, D); k_new/v_new: (B, 1, K, D).
    The new token attends to the full cache plus itself.  ``window`` is
    enforced structurally by the cache being window-sized, so no masking is
    needed here beyond validity of entries.
    """
    B, _, H, D = q.shape
    T, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    scale = scale if scale is not None else D ** -0.5
    k = jnp.concatenate([k_cache, k_new], axis=1)        # (B, T+1, K, D)
    v = jnp.concatenate([v_cache, v_new], axis=1)
    qg = q.reshape(B, K, G, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k, preferred_element_type=F32) * scale
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v, preferred_element_type=F32)
    return o.reshape(B, 1, H, v.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked cross-entropy (vocab can be 150k; never materialise full logits)
# ---------------------------------------------------------------------------

def chunked_ce_loss(hidden: jax.Array, w_vocab: jax.Array,
                    labels: jax.Array, *, chunk: int = 512) -> jax.Array:
    """hidden: (B, S, d); w_vocab: (d, V); labels: (B, S) int32.

    Scans over sequence chunks so the (tokens, V) logit block peaks at
    B*chunk*V instead of B*S*V.  Each chunk is rematerialised in backward.
    """
    B, S, d = hidden.shape
    V = w_vocab.shape[1]
    chunk = min(chunk, S)
    n = S // chunk

    @jax.checkpoint
    def chunk_loss(h_c: jax.Array, y_c: jax.Array) -> jax.Array:
        logits = jnp.einsum("btd,dv->btv", h_c, w_vocab,
                            preferred_element_type=F32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    hs = hidden[:, :n * chunk].reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    ys = labels[:, :n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)

    def body(tot, xs):
        h_c, y_c = xs
        return tot + chunk_loss(h_c, y_c), None

    total, _ = jax.lax.scan(body, jnp.zeros((), F32), (hs, ys))
    rem = S - n * chunk
    if rem:
        total = total + chunk_loss(hidden[:, n * chunk:], labels[:, n * chunk:])
    return total / (B * S)


# ---------------------------------------------------------------------------
# Time embedding (flow / DiT conditioning)
# ---------------------------------------------------------------------------

def timestep_embedding(t: jax.Array, dim: int, max_period: float = 1e4
                       ) -> jax.Array:
    """t: (B,) in [0,1] -> (B, dim) sinusoidal features."""
    half = dim // 2
    freqs = jnp.exp(-jnp.log(max_period) * jnp.arange(half, dtype=F32) / half)
    args = t.astype(F32)[:, None] * freqs[None, :] * 1000.0
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb
