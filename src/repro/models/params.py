"""Parameter specification trees.

A model is described by a pytree whose leaves are :class:`P` — (shape,
logical axes, initializer).  From one spec tree we derive:

* ``init(spec, key, dtype)``      -> params pytree (same structure)
* ``axes_tree(spec)``             -> pytree of logical-axes tuples (for sharding)
* ``shape_tree(spec, dtype)``     -> pytree of ShapeDtypeStruct (for dry-run)

Keeping shapes/axes/init in a single place guarantees the sharding spec can
never drift from the parameter structure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | small
    scale: Optional[float] = None  # stddev override; default 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_p(x: Any) -> bool:
    return isinstance(x, P)


def _init_leaf(p: P, key: jax.Array, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        scale = p.scale if p.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)
    if p.init == "small":
        scale = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)
    raise ValueError(f"unknown init {p.init}")


def init(spec, key: jax.Array, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(spec, is_leaf=_is_p)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [_init_leaf(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def axes_tree(spec):
    return jax.tree.map(lambda p: p.axes, spec, is_leaf=_is_p)


def shape_tree(spec, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec, is_leaf=_is_p)


def n_params(spec) -> int:
    return sum(int(np.prod(p.shape)) for p in
               jax.tree.leaves(spec, is_leaf=_is_p))


# Logical axes eligible for "model"-mesh-axis sharding, in priority order:
# for each param leaf the FIRST axis listed here whose dim divides the
# model-parallel size is the one sharded (repro.distributed.PartitionPlan).
# Experts come before heads before wide hidden dims before the embed
# fallback, so MoE expert tables shard expert-parallel, attention/MLA
# projections shard head-parallel, and dense backbone leaves (time/cond
# embeds, norms aside) fall back to FSDP-style embed sharding.  Axes not
# listed — norm scales, head_dim, conv taps, the MLA LoRA bottlenecks, the
# scan "layers" dim — are never sharded: either tiny, or splitting them
# would cut a contraction XLA cannot partition profitably at this scale.
MODEL_SHARDABLE: Tuple[str, ...] = (
    "experts", "experts_mdl",
    "heads", "kv_heads", "ssm_heads",
    "inner", "mlp", "moe_f",
    "vocab",
    "embed", "embed_r", "moe_in", "moe_out",
    "cond", "time", "latent",
)


def model_shard_dim(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                    mp: int) -> Optional[int]:
    """The dim index of a param leaf to shard over the "model" mesh axis
    (size ``mp``), or None to replicate — the per-leaf decision the
    PartitionPlan is built from.  Purely a function of the declared logical
    axes, so the plan can never drift from the parameter structure."""
    if mp <= 1:
        return None
    for name in MODEL_SHARDABLE:
        for i, ax in enumerate(axes):
            if ax == name and shape[i] >= mp and shape[i] % mp == 0:
                return i
    return None


def stack(spec, n: int, axis_name: Optional[str] = "layers"):
    """Add a leading stacking dim (for lax.scan over layers)."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale),
        spec, is_leaf=_is_p)
