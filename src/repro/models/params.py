"""Parameter specification trees.

A model is described by a pytree whose leaves are :class:`P` — (shape,
logical axes, initializer).  From one spec tree we derive:

* ``init(spec, key, dtype)``      -> params pytree (same structure)
* ``axes_tree(spec)``             -> pytree of logical-axes tuples (for sharding)
* ``shape_tree(spec, dtype)``     -> pytree of ShapeDtypeStruct (for dry-run)

Keeping shapes/axes/init in a single place guarantees the sharding spec can
never drift from the parameter structure.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | small
    scale: Optional[float] = None  # stddev override; default 1/sqrt(fan_in)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_p(x: Any) -> bool:
    return isinstance(x, P)


def _init_leaf(p: P, key: jax.Array, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":
        fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
        scale = p.scale if p.scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)
    if p.init == "small":
        scale = p.scale if p.scale is not None else 0.02
        return (jax.random.normal(key, p.shape, jnp.float32) * scale).astype(dtype)
    raise ValueError(f"unknown init {p.init}")


def init(spec, key: jax.Array, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(spec, is_leaf=_is_p)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [_init_leaf(p, k, dtype) for p, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def axes_tree(spec):
    return jax.tree.map(lambda p: p.axes, spec, is_leaf=_is_p)


def shape_tree(spec, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec, is_leaf=_is_p)


def n_params(spec) -> int:
    return sum(int(np.prod(p.shape)) for p in
               jax.tree.leaves(spec, is_leaf=_is_p))


def stack(spec, n: int, axis_name: Optional[str] = "layers"):
    """Add a leading stacking dim (for lax.scan over layers)."""
    return jax.tree.map(
        lambda p: P((n,) + p.shape, (axis_name,) + p.axes, p.init, p.scale),
        spec, is_leaf=_is_p)
