"""Mamba2 SSD block (state-space duality, arXiv:2405.21060).

The sequence transform is the chunked SSD algorithm: within-chunk terms via
the quadratic "attention-like" dual form, across-chunk terms via a scanned
state recurrence.  This is exactly the structure the Pallas ``ssd_scan``
kernel implements on TPU; this module is the jnp reference / XLA path.

Shapes (per layer):
  x   (B, L, H, P)   values (H = d_inner/head_dim heads, P = head_dim)
  dt  (B, L, H)      positive step sizes (softplus)
  A   (H,)           negative decay rates
  Bm  (B, L, N)      input projections (single state group, mamba2 default)
  Cm  (B, L, N)      output projections
  state (B, H, P, N) recurrent state (decode cache — O(1) in context length!)
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import layers
from repro.models.params import P

F32 = layers.F32


class SSMCache(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, conv_dim) trailing conv inputs
    state: jax.Array   # (B, H, P, N)


def dims(cfg: ArchConfig) -> Dict[str, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_dim = d_in + 2 * s.d_state
    return dict(d_in=d_in, H=H, P=s.head_dim, N=s.d_state,
                conv_dim=conv_dim, Q=s.chunk, d_conv=s.d_conv)


def spec(cfg: ArchConfig) -> Dict:
    d = cfg.d_model
    m = dims(cfg)
    proj_out = 2 * m["d_in"] + 2 * m["N"] + m["H"]
    return {
        "in_proj": P((d, proj_out), ("embed", "inner")),
        "conv_w": P((m["d_conv"], m["conv_dim"]), ("conv", "inner"), "small"),
        "conv_b": P((m["conv_dim"],), ("inner",), "zeros"),
        "a_log": P((m["H"],), ("ssm_heads",), "small", 0.5),
        "d_skip": P((m["H"],), ("ssm_heads",), "ones"),
        "dt_bias": P((m["H"],), ("ssm_heads",), "small", 0.5),
        "norm": P((m["d_in"],), ("inner",), "ones"),
        "out_proj": P((m["d_in"], d), ("inner", "embed_r")),
    }


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array,
                 init_state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. u: (B, L, C); w: (K, C); returns (B, L, C)."""
    K = w.shape[0]
    if init_state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = init_state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = jnp.zeros_like(u, dtype=F32)
    for i in range(K):
        out = out + up[:, i:i + u.shape[1]].astype(F32) * w[i].astype(F32)
    return jax.nn.silu(out + b.astype(F32)).astype(u.dtype)


def _segsum_chunk(dA: jax.Array) -> jax.Array:
    """dA: (..., Q) -> (..., Q, Q) with out[i,j] = sum_{r=j+1..i} dA_r (i>=j)."""
    Q = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]             # sum_{j+1..i}
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
                cm: jax.Array, chunk: int,
                init_state: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    B, L, H, Pd = x.shape
    N = bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0, (L, Q)
    nc = L // Q

    xf = x.astype(F32).reshape(B, nc, Q, H, Pd)
    dtf = dt.astype(F32).reshape(B, nc, Q, H)
    bf = bm.astype(F32).reshape(B, nc, Q, N)
    cf = cm.astype(F32).reshape(B, nc, Q, N)
    dA = dtf * a.astype(F32)                               # (B,nc,Q,H)

    # --- within-chunk (dual / quadratic form) ---
    seg = _segsum_chunk(jnp.moveaxis(dA, -1, -2))          # (B,nc,H,Q,Q)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bcqn,bckn->bcqk", cf, bf)         # (B,nc,Q,Q)
    att = scores[:, :, None] * decay                       # (B,nc,H,Q,Q)
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp", att, dtf, xf)

    # --- chunk states ---
    cum = jnp.cumsum(dA, axis=2)                           # (B,nc,Q,H)
    total = cum[:, :, -1:]                                 # (B,nc,1,H)
    decay_to_end = jnp.exp(total - cum)                    # (B,nc,Q,H)
    chunk_states = jnp.einsum("bcqh,bcqh,bcqn,bcqhp->bchpn",
                              decay_to_end, dtf, bf, xf)   # (B,nc,H,P,N)

    # --- inter-chunk recurrence (scan over chunks) ---
    chunk_decay = jnp.exp(total[:, :, 0])                  # (B,nc,H)
    h0 = (jnp.zeros((B, H, Pd, N), F32) if init_state is None
          else init_state.astype(F32))

    def body(h, inp):
        cd, cs = inp                                       # (B,H), (B,H,P,N)
        h_out = h                                          # state entering chunk
        h_new = h * cd[..., None, None] + cs
        return h_new, h_out

    hs_final, h_prevs = jax.lax.scan(
        body, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(chunk_states, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                  # (B,nc,H,P,N)

    # --- off-chunk contribution ---
    state_decay = jnp.exp(cum)                             # decay from chunk start
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", cf, state_decay, h_prevs)

    y = (y_diag + y_off).reshape(B, L, H, Pd)
    return y.astype(x.dtype), hs_final


def ssd_decode_step(state: jax.Array, x: jax.Array, dt: jax.Array,
                    a: jax.Array, bm: jax.Array, cm: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence. state (B,H,P,N); x (B,H,P); dt (B,H);
    bm/cm (B,N). Returns (y (B,H,P), new_state)."""
    dA = jnp.exp(dt.astype(F32) * a.astype(F32))           # (B,H)
    upd = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(F32), bm.astype(F32),
                     x.astype(F32))
    new_state = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, cm.astype(F32))
    return y.astype(x.dtype), new_state


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    m = dims(cfg)
    z, xin, bc, dt = jnp.split(
        zxbcdt, [m["d_in"], 2 * m["d_in"], 2 * m["d_in"] + 2 * m["N"]],
        axis=-1)
    return z, xin, bc, dt


def apply_full(p: Dict, cfg: ArchConfig, x: jax.Array, *,
               return_cache: bool = False
               ) -> Tuple[jax.Array, Optional[SSMCache]]:
    """Full-sequence SSD block. x: (B, S, d)."""
    m = dims(cfg)
    B, S, d = x.shape
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"],
                        preferred_element_type=F32).astype(x.dtype)
    z, xin, bc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, bc], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    xin = conv_out[..., :m["d_in"]]
    bm = conv_out[..., m["d_in"]:m["d_in"] + m["N"]]
    cm = conv_out[..., m["d_in"] + m["N"]:]
    dt = jax.nn.softplus(dt_raw.astype(F32) + p["dt_bias"].astype(F32))
    a = -jnp.exp(p["a_log"].astype(F32))
    xh = xin.reshape(B, S, m["H"], m["P"])
    y, final_state = ssd_chunked(xh, dt, a, bm, cm, m["Q"])
    y = y + xh * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, S, m["d_in"])
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(F32)).astype(x.dtype),
                       cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"],
                     preferred_element_type=layers.reduce_dtype()
                     ).astype(x.dtype)
    cache = None
    if return_cache:
        conv_tail = conv_in[:, S - (m["d_conv"] - 1):, :]
        cache = SSMCache(conv=conv_tail, state=final_state)
    return out, cache


def apply_decode(p: Dict, cfg: ArchConfig, x: jax.Array, cache: SSMCache
                 ) -> Tuple[jax.Array, SSMCache]:
    """One-token decode. x: (B, 1, d)."""
    m = dims(cfg)
    B = x.shape[0]
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"],
                        preferred_element_type=F32).astype(x.dtype)
    z, xin, bc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, bc], axis=-1)          # (B,1,conv_dim)
    full = jnp.concatenate([cache.conv, conv_in], axis=1)  # (B,d_conv,cd)
    w, b = p["conv_w"], p["conv_b"]
    co = (full.astype(F32) * w.astype(F32)[None]).sum(axis=1) + b.astype(F32)
    co = jax.nn.silu(co).astype(x.dtype)                   # (B, conv_dim)
    xin1 = co[:, :m["d_in"]].reshape(B, m["H"], m["P"])
    bm1 = co[:, m["d_in"]:m["d_in"] + m["N"]]
    cm1 = co[:, m["d_in"] + m["N"]:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(F32) + p["dt_bias"].astype(F32))
    a = -jnp.exp(p["a_log"].astype(F32))
    y, new_state = ssd_decode_step(cache.state, xin1, dt, a, bm1, cm1)
    y = y + xin1 * p["d_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(B, 1, m["d_in"])
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z.astype(F32)).astype(x.dtype),
                       cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"],
                     preferred_element_type=F32).astype(x.dtype)
    new_conv = full[:, 1:, :]
    return out, SSMCache(conv=new_conv, state=new_state)


def init_cache_shapes(cfg: ArchConfig, batch: int):
    m = dims(cfg)
    return {
        "conv": ((batch, m["d_conv"] - 1, m["conv_dim"]),
                 ("batch", None, "inner")),
        "state": ((batch, m["H"], m["P"], m["N"]),
                  ("batch", "ssm_heads", None, None)),
    }
