"""GQA attention block: param spec + full-sequence / decode application.

Supports grouped-query attention, optional per-head q/k RMSNorm (Qwen3),
sliding windows (enables long_500k for dense archs) and KV caches.

Sharding: ``wq``/``wo`` carry the ``"heads"`` logical axis and ``wk``/``wv``
carry ``"kv_heads"``, so under ``dist.model_parallel>1`` the
:class:`~repro.distributed.PartitionPlan` shards the projections
head-parallel when the head count divides the model axis (``MODEL_SHARDABLE``
priority; GQA kv heads may stay replicated when K < mp).  Declared here via
:class:`repro.models.params.P` — the distributed layer never names modules.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ArchConfig
from repro.models import layers
from repro.models.params import P


class KVCache(NamedTuple):
    k: jax.Array     # (B, T, K, D)
    v: jax.Array     # (B, T, K, D)


def spec(cfg: ArchConfig) -> Dict:
    d, H, K = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    s = {
        "wq": P((d, H, hd), ("embed", "heads", "head_dim")),
        "wk": P((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((H, hd, d), ("heads", "head_dim", "embed_r")),
    }
    if cfg.qk_norm:
        s["q_norm"] = P((hd,), ("head_dim",), "ones")
        s["k_norm"] = P((hd,), ("head_dim",), "ones")
    return s


def _qkv(p: Dict, cfg: ArchConfig, x: jax.Array,
         positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"],
                   preferred_element_type=layers.F32).astype(x.dtype)
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"],
                   preferred_element_type=layers.F32).astype(x.dtype)
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"],
                   preferred_element_type=layers.F32).astype(x.dtype)
    if cfg.qk_norm:
        q = layers.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = layers.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = layers.apply_rope(q, positions, cfg.rope_theta)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_full(p: Dict, cfg: ArchConfig, x: jax.Array, *,
               causal: bool = True, window: int = 0,
               positions: Optional[jax.Array] = None,
               return_cache: bool = False
               ) -> Tuple[jax.Array, Optional[KVCache]]:
    """Full-sequence attention (train / prefill). x: (B, S, d)."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    q, k, v = _qkv(p, cfg, x, positions)
    o = layers.attention_chunked(q, k, v, causal=causal, window=window,
                                 q_positions=positions, k_positions=positions)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=layers.reduce_dtype()
                     ).astype(x.dtype)
    cache = KVCache(k, v) if return_cache else None
    return out, cache


def apply_decode(p: Dict, cfg: ArchConfig, x: jax.Array, cache: KVCache,
                 pos: jax.Array, *, window: int = 0
                 ) -> Tuple[jax.Array, KVCache]:
    """One-token decode. x: (B, 1, d); pos: scalar int32 position index.

    The cache holds the previous ``T`` KV entries (window-sized when sliding
    windows are active).  Returns output and the rolled cache.
    """
    B = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k_new, v_new = _qkv(p, cfg, x, positions)
    o = layers.attention_decode(q, cache.k, cache.v, k_new, v_new,
                                window=window)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"],
                     preferred_element_type=layers.reduce_dtype()
                     ).astype(x.dtype)
    # roll the cache: drop the oldest entry, append the new one (ring-buffer
    # semantics; keeps the cache shape static for jit)
    k_c = jnp.concatenate([cache.k[:, 1:], k_new], axis=1)
    v_c = jnp.concatenate([cache.v[:, 1:], v_new], axis=1)
    return out, KVCache(k_c, v_c)


def init_cache_shape(cfg: ArchConfig, batch: int, cache_len: int
                     ) -> Tuple[Tuple[int, ...], Tuple]:
    hd = cfg.resolved_head_dim
    shape = (batch, cache_len, cfg.n_kv_heads, hd)
    axes = ("batch", "cache_seq", "kv_heads", "head_dim")
    return shape, axes
