"""Unified Backbone covering all six assigned architecture families.

One composable model definition, driven entirely by :class:`ArchConfig`:

* dense / vlm / audio : uniform [ln, attn, ln, SwiGLU] blocks
* moe                 : same, FFN replaced by MoE (optional leading dense layers)
* ssm                 : uniform [ln, mamba2-SSD] blocks
* hybrid (zamba2)     : groups of SSM blocks + a periodically applied *shared*
                        attention/MLP block (one param set reused at each site)
* dit (flux-like)     : bidirectional blocks with adaLN-zero time/cond
                        modulation — the paper's own model family

Layers are stacked and driven by ``lax.scan`` so compile time and HLO size are
O(1) in depth.  Three entry points: ``forward`` (train), ``prefill``,
``decode`` (one token against a KV/state cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as shlib
from repro.config import ArchConfig
from repro.models import attention, layers, mla, moe, ssm
from repro.models.params import P, axes_tree, stack

F32 = jnp.float32

ATTN_FAMILIES = ("dense", "moe", "vlm", "audio", "dit")


# ---------------------------------------------------------------------------
# Block specs
# ---------------------------------------------------------------------------

def _attn_spec(cfg: ArchConfig) -> Dict:
    return mla.spec(cfg) if cfg.mla else attention.spec(cfg)


def _attn_block_spec(cfg: ArchConfig, ffn: str) -> Dict:
    d = cfg.d_model
    s = {
        "ln1": layers.rmsnorm_spec(d),
        "attn": _attn_spec(cfg),
        "ln2": layers.rmsnorm_spec(d),
    }
    s["ffn"] = moe.spec(cfg) if ffn == "moe" else layers.mlp_spec(d, cfg.d_ff)
    if cfg.family == "dit":
        # adaLN-zero: cond vector -> 6 modulation params per block
        s["ada"] = P((d, 6 * d), ("embed", None), "zeros")
    return s


def _ssm_block_spec(cfg: ArchConfig) -> Dict:
    return {"ln": layers.rmsnorm_spec(cfg.d_model), "ssm": ssm.spec(cfg)}


class Backbone:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.n_prefix = cfg.frontend.n_tokens
        # logical-axes trees of the UNSTACKED block specs — used by the
        # weight-gathered FSDP constraint inside scan bodies (sharding.py)
        self._axes_mlp = axes_tree(_attn_block_spec(cfg, "mlp"))
        self._axes_moe = (axes_tree(_attn_block_spec(cfg, "moe"))
                          if cfg.moe and cfg.moe.n_experts else None)
        self._axes_ssm = (axes_tree(_ssm_block_spec(cfg))
                          if cfg.family in ("ssm", "hybrid") else None)

    def _gather(self, blk_p: Dict) -> Dict:
        """Constrain a sliced block's weights to the gathered layout."""
        if "ssm" in blk_p:
            return shlib.constrain_params(blk_p, self._axes_ssm)
        if "router" in blk_p.get("ffn", {}):
            return shlib.constrain_params(blk_p, self._axes_moe)
        return shlib.constrain_params(blk_p, self._axes_mlp)

    # ------------------------------------------------------------------ spec
    def spec(self) -> Dict:
        cfg = self.cfg
        d = cfg.d_model
        s: Dict[str, Any] = {
            "embed": P((cfg.vocab_size, d), ("vocab", "embed"), "small"),
            "final_norm": layers.rmsnorm_spec(d),
        }
        if not cfg.tie_embeddings:
            s["lm_head"] = P((d, cfg.vocab_size), ("embed", "vocab"))
        if cfg.frontend.kind != "none":
            s["frontend_proj"] = P((cfg.frontend.embed_dim, d),
                                   (None, "embed"))
        fam = cfg.family
        if fam == "ssm":
            s["blocks"] = stack(_ssm_block_spec(cfg), cfg.n_layers)
        elif fam == "hybrid":
            hy = cfg.hybrid
            n_groups = cfg.n_layers // hy.attn_every
            inner = stack(_ssm_block_spec(cfg), hy.attn_every, None)
            s["blocks"] = stack(inner, n_groups, "groups")
            s["shared_attn"] = _attn_block_spec(cfg, "mlp")
        elif fam in ("moe",):
            fk = cfg.moe.first_k_dense
            if fk:
                s["dense_blocks"] = stack(_attn_block_spec(cfg, "mlp"), fk)
            s["blocks"] = stack(_attn_block_spec(cfg, "moe"),
                                cfg.n_layers - fk)
        else:  # dense / vlm / audio / dit
            s["blocks"] = stack(_attn_block_spec(cfg, "mlp"), cfg.n_layers)
        return s

    # ----------------------------------------------------------- block apply
    def _attn_block(self, p: Dict, x: jax.Array, *, causal: bool, window: int,
                    positions: jax.Array, cond: Optional[jax.Array],
                    return_cache: bool) -> Tuple[jax.Array, Any, Dict]:
        cfg = self.cfg
        p = self._gather(p)
        aux: Dict[str, jax.Array] = {}
        if cfg.family == "dit" and cond is not None:
            mod = jnp.einsum("bd,de->be", cond, p["ada"],
                             preferred_element_type=F32).astype(x.dtype)
            (sh_a, sc_a, g_a, sh_m, sc_m, g_m) = jnp.split(mod, 6, axis=-1)
            h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
            h = h * (1 + sc_a[:, None]) + sh_a[:, None]
            attn_fn = mla.apply_full if cfg.mla else attention.apply_full
            a_out, cache = attn_fn(p["attn"], cfg, h, causal=causal,
                                   window=window, positions=positions,
                                   return_cache=return_cache)
            x = x + g_a[:, None] * a_out
            h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
            h = h * (1 + sc_m[:, None]) + sh_m[:, None]
            x = x + g_m[:, None] * layers.mlp(p["ffn"], h)
            return x, cache, aux
        h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
        attn_fn = mla.apply_full if cfg.mla else attention.apply_full
        a_out, cache = attn_fn(p["attn"], cfg, h, causal=causal, window=window,
                               positions=positions, return_cache=return_cache)
        x = x + a_out
        h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "router" in p["ffn"]:
            f_out, aux = moe.apply(p["ffn"], cfg, h)
        else:
            f_out = layers.mlp(p["ffn"], h)
        x = x + f_out
        return x, cache, aux

    def _attn_block_decode(self, p: Dict, x: jax.Array, cache, pos,
                           *, window: int) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        p = self._gather(p)
        h = layers.rmsnorm(p["ln1"], x, cfg.norm_eps)
        dec_fn = mla.apply_decode if cfg.mla else attention.apply_decode
        a_out, cache = dec_fn(p["attn"], cfg, h, cache, pos, window=window)
        x = x + a_out
        h = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
        if "router" in p["ffn"]:
            f_out, _ = moe.apply(p["ffn"], cfg, h)
        else:
            f_out = layers.mlp(p["ffn"], h)
        return x + f_out, cache

    def _ssm_block(self, p: Dict, x: jax.Array, *, return_cache: bool
                   ) -> Tuple[jax.Array, Any]:
        p = self._gather(p)
        h = layers.rmsnorm(p["ln"], x, self.cfg.norm_eps)
        out, cache = ssm.apply_full(p["ssm"], self.cfg, h,
                                    return_cache=return_cache)
        return x + out, cache

    def _ssm_block_decode(self, p: Dict, x: jax.Array, cache
                          ) -> Tuple[jax.Array, Any]:
        p = self._gather(p)
        h = layers.rmsnorm(p["ln"], x, self.cfg.norm_eps)
        out, cache = ssm.apply_decode(p["ssm"], self.cfg, h, cache)
        return x + out, cache

    # ------------------------------------------------------------- embedding
    def embed_inputs(self, params: Dict, tokens: jax.Array,
                     prefix_embed: Optional[jax.Array] = None) -> jax.Array:
        emb = shlib.constrain_params(params["embed"], ("vocab", "embed"))
        x = jnp.take(emb, tokens, axis=0)
        x = shlib.constrain_act(x, ("batch", "seq", "embed"))
        if prefix_embed is not None:
            pe = jnp.einsum("bne,ed->bnd", prefix_embed.astype(x.dtype),
                            params["frontend_proj"],
                            preferred_element_type=F32).astype(x.dtype)
            x = jnp.concatenate([pe, x], axis=1)
        return x

    def logits(self, params: Dict, hidden: jax.Array) -> jax.Array:
        head = self.head_matrix(params)
        return jnp.einsum("...d,dv->...v", hidden, head,
                          preferred_element_type=F32)

    def head_matrix(self, params: Dict) -> jax.Array:
        if self.cfg.tie_embeddings:
            head = params["embed"].T
            return shlib.constrain_params(head, ("embed", "vocab"))
        return shlib.constrain_params(params["lm_head"], ("embed", "vocab"))

    # ------------------------------------------------------- full-seq driver
    def forward_embeds(self, params: Dict, x: jax.Array, *,
                       causal: bool = True, window: int = 0,
                       cond: Optional[jax.Array] = None,
                       remat: bool = False, return_caches: bool = False
                       ) -> Tuple[jax.Array, Any, Dict]:
        """Run all blocks over embedded inputs x: (B, S, d).

        Returns (hidden, caches_or_None, aux_losses).
        """
        cfg = self.cfg
        S = x.shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
        aux_tot: Dict[str, jax.Array] = {}

        def add_aux(aux):
            for k, v in aux.items():
                aux_tot[k] = aux_tot.get(k, 0.0) + v

        fam = cfg.family

        if fam == "ssm":
            def body(h, blk_p):
                h, cache = self._ssm_block(blk_p, h,
                                           return_cache=return_caches)
                return h, cache
            if remat:
                body = jax.checkpoint(body)
            x, caches = jax.lax.scan(body, x, params["blocks"])
            x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
            return x, caches, aux_tot

        if fam == "hybrid":
            shared_p = params["shared_attn"]

            def group_body(h, grp_p):
                def inner(h2, blk_p):
                    h2, c = self._ssm_block(blk_p, h2,
                                            return_cache=return_caches)
                    return h2, c
                h, ssm_caches = jax.lax.scan(inner, h, grp_p)
                h, attn_cache, _ = self._attn_block(
                    shared_p, h, causal=causal, window=window,
                    positions=positions, cond=cond,
                    return_cache=return_caches)
                return h, (ssm_caches, attn_cache)
            if remat:
                group_body = jax.checkpoint(group_body)
            x, caches = jax.lax.scan(group_body, x, params["blocks"])
            x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
            return x, caches, aux_tot

        # attention families
        def body(h, blk_p):
            h, cache, aux = self._attn_block(
                blk_p, h, causal=causal, window=window, positions=positions,
                cond=cond, return_cache=return_caches)
            return h, (cache, aux)
        if remat:
            body = jax.checkpoint(body)

        caches_d = None
        if fam == "moe" and cfg.moe.first_k_dense:
            def body_d(h, blk_p):
                h, cache, aux = self._attn_block(
                    blk_p, h, causal=causal, window=window,
                    positions=positions, cond=cond,
                    return_cache=return_caches)
                return h, (cache, aux)
            if remat:
                body_d = jax.checkpoint(body_d)
            x, (caches_d, _) = jax.lax.scan(body_d, x, params["dense_blocks"])

        x, (caches, auxs) = jax.lax.scan(body, x, params["blocks"])
        add_aux({k: v.sum() for k, v in auxs.items()})
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        all_caches = ((caches_d, caches) if caches_d is not None else caches)
        return x, all_caches, aux_tot

    # --------------------------------------------------------- decode driver
    def decode_embeds(self, params: Dict, x: jax.Array, caches, pos,
                      *, window: int = 0) -> Tuple[jax.Array, Any]:
        """One-token step. x: (B, 1, d); caches as returned by prefill /
        init_cache; pos: scalar absolute position of the new token."""
        cfg = self.cfg
        fam = cfg.family

        if fam == "ssm":
            def body(h, xs):
                blk_p, cache = xs
                h, cache = self._ssm_block_decode(blk_p, h, cache)
                return h, cache
            x, caches = jax.lax.scan(body, x, (params["blocks"], caches))
            x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
            return x, caches

        if fam == "hybrid":
            shared_p = params["shared_attn"]

            def group_body(h, xs):
                grp_p, (ssm_caches, attn_cache) = xs

                def inner(h2, xs2):
                    blk_p, c = xs2
                    h2, c = self._ssm_block_decode(blk_p, h2, c)
                    return h2, c
                h, ssm_caches = jax.lax.scan(inner, h, (grp_p, ssm_caches))
                h, attn_cache = self._attn_block_decode(
                    shared_p, h, attn_cache, pos, window=window)
                return h, (ssm_caches, attn_cache)
            x, caches = jax.lax.scan(group_body, x, (params["blocks"], caches))
            x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
            return x, caches

        def body(h, xs):
            blk_p, cache = xs
            h, cache = self._attn_block_decode(blk_p, h, cache, pos,
                                               window=window)
            return h, cache

        if fam == "moe" and cfg.moe.first_k_dense:
            caches_d, caches_m = caches

            def body_d(h, xs):
                blk_p, cache = xs
                h, cache = self._attn_block_decode(blk_p, h, cache, pos,
                                                   window=window)
                return h, cache
            x, caches_d = jax.lax.scan(body_d, x,
                                       (params["dense_blocks"], caches_d))
            x, caches_m = jax.lax.scan(body, x, (params["blocks"], caches_m))
            x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
            return x, (caches_d, caches_m)

        x, caches = jax.lax.scan(body, x, (params["blocks"], caches))
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, caches

    # ----------------------------------------------------------- cache specs
    def cache_specs(self, batch: int, cache_len: int) -> Any:
        """Pytree of (shape, logical_axes) matching the decode cache
        structure (used for zeros-init and for dry-run ShapeDtypeStructs)."""
        cfg = self.cfg
        fam = cfg.family

        def attn_cache_spec(lead: Tuple[int, ...] = ()):
            la = ("layers",) * len(lead)
            if cfg.mla:
                shp = mla.init_cache_shapes(cfg, batch, cache_len)
                return mla.MLACache(
                    c_kv=(lead + shp["c_kv"][0], la + shp["c_kv"][1]),
                    k_rope=(lead + shp["k_rope"][0], la + shp["k_rope"][1]))
            shape, axes = attention.init_cache_shape(cfg, batch, cache_len)
            return attention.KVCache(k=(lead + shape, la + axes),
                                     v=(lead + shape, la + axes))

        def ssm_cache_spec(lead: Tuple[int, ...] = ()):
            la = ("layers",) * len(lead)
            shp = ssm.init_cache_shapes(cfg, batch)
            return ssm.SSMCache(
                conv=(lead + shp["conv"][0], la + shp["conv"][1]),
                state=(lead + shp["state"][0], la + shp["state"][1]))

        if fam == "ssm":
            return ssm_cache_spec((cfg.n_layers,))
        if fam == "hybrid":
            hy = cfg.hybrid
            n_groups = cfg.n_layers // hy.attn_every
            return (ssm_cache_spec((n_groups, hy.attn_every)),
                    attn_cache_spec((n_groups,)))
        if fam == "moe" and cfg.moe.first_k_dense:
            fk = cfg.moe.first_k_dense
            return (attn_cache_spec((fk,)),
                    attn_cache_spec((cfg.n_layers - fk,)))
        return attn_cache_spec((cfg.n_layers,))
