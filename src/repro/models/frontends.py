"""Stub modality frontends (assignment carve-out).

The [vlm] and [audio] architectures specify the transformer backbone only;
the modality frontend (ViT vision encoder / EnCodec conv feature extractor)
is a STUB: ``embeddings()`` produces deterministic precomputed patch/frame
embeddings of the right shape, and ``input_specs`` passes equivalent
ShapeDtypeStructs at dry-run time.  The decoder that consumes them is fully
implemented.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import registry
from repro.config import ArchConfig, FrontendConfig


@registry.register("frontend", "none")
class NoFrontend:
    def __init__(self, cfg: FrontendConfig):
        self.cfg = cfg

    def embeddings(self, key: jax.Array, batch: int) -> None:
        return None


class _StubFrontend:
    """Deterministic hash-seeded embedding generator standing in for a frozen
    encoder; the real pipeline would run InternViT / EnCodec here and the
    preprocessing cache (repro.core.preprocess) would store its outputs."""

    def __init__(self, cfg: FrontendConfig):
        assert cfg.n_tokens > 0 and cfg.embed_dim > 0
        self.cfg = cfg

    def embeddings(self, key: jax.Array, batch: int) -> jax.Array:
        return jax.random.normal(
            key, (batch, self.cfg.n_tokens, self.cfg.embed_dim),
            jnp.float32).astype(jnp.bfloat16)


@registry.register("frontend", "vision")
class VisionFrontendStub(_StubFrontend):
    """InternViT patch embeddings (InternVL2, arXiv:2404.16821)."""


@registry.register("frontend", "audio")
class AudioFrontendStub(_StubFrontend):
    """EnCodec conditioning frames (MusicGen, arXiv:2306.05284)."""


def build(cfg: FrontendConfig):
    return registry.build("frontend", cfg.kind, cfg)
