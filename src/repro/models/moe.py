"""Mixture-of-Experts layer (grok-1: 8e top-2; deepseek-v2: 2 shared + 160e top-6).

Dispatch is scatter-based (megablocks-style slots) rather than the GShard
(T, E, C) one-hot einsum: a (T·k,) slot index scatters tokens into a
(G, E, C, d) buffer, experts run batched over the stacked weights, and a
gather brings results back.  This avoids materialising the (T, E, C)
dispatch tensor (which at deepseek-v2 scale would be ~4 GB/device) while
remaining fully static-shaped for jit/pjit.

Sharding modes:
  * "tensor" (baseline): each expert's hidden dim sharded over "model"
    (always divides); the expert d_model dims carry the distinct logical
    axes "moe_in"/"moe_out" so the weight-gathered-FSDP constraint can keep
    expert weights SHARDED while gathering the (much smaller) dense weights
    — gathering 160 experts per layer would invert the win.
  * "ep_model" (REPRO_MOE_MODE=ep_model): experts sharded over the "model"
    axis (requires E % model == 0, e.g. deepseek's 160); the dispatch buffer
    is resharded group-parallel -> expert-parallel around the expert matmul
    (the classic all-to-all pair), per-expert f unsharded.
  * "dense" (REPRO_MOE_MODE=dense): small-E mode — compute every expert on
    every token and mix by dense gates; E/top_k FLOP overcompute buys
    dispatch-free communication (grok-1: 13.6× less traffic, EXPERIMENTS.md
    §Perf iteration 2).

Under ``dist.model_parallel>1`` the training-side
:class:`~repro.distributed.PartitionPlan` reads these same logical axes:
``"experts"``/``"experts_mdl"`` rank first in ``MODEL_SHARDABLE``, so the
stacked expert tables shard expert-parallel whenever E divides the model
axis, falling back to the wide ``f`` dim and then embed (FSDP) sharding —
declared here via :class:`repro.models.params.P`, never by module name.
"""
from __future__ import annotations

import functools
import os
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import sharding as shlib
from repro.config import ArchConfig
from repro.models import layers
from repro.models.params import P

F32 = layers.F32


def _edot(eq: str, a: jax.Array, w: jax.Array, pet) -> jax.Array:
    """Expert einsum. The CPU backend's DotThunk cannot execute some
    bf16×bf16→f32 batched dots (test/CI path only) — upcast there; on TPU
    keep bf16 operands with the requested accumulation dtype."""
    if jax.default_backend() == "cpu" and a.dtype == jnp.bfloat16:
        return jnp.einsum(eq, a.astype(F32), w.astype(F32))
    return jnp.einsum(eq, a, w, preferred_element_type=pet)


def moe_mode(cfg: ArchConfig) -> str:
    """tensor (default) | ep_model | dense — see module docstring."""
    return os.environ.get("REPRO_MOE_MODE", cfg.moe.sharding or "tensor")


def spec(cfg: ArchConfig) -> Dict:
    m = cfg.moe
    d, E, f = cfg.d_model, m.n_experts, m.expert_d_ff
    mode = moe_mode(cfg)
    if mode == "ep_model":
        # experts over the model axis (E % 16 == 0, e.g. deepseek's 160);
        # per-expert f stays unsharded, d fsdp-sharded + gathered at use
        ex, fa = "experts_mdl", "moe_f"
    else:
        ex, fa = "experts", "mlp"
    s = {
        "router": P((d, E), ("embed", None), "small"),
        "w_gate": P((E, d, f), (ex, "moe_in", fa)),
        "w_up": P((E, d, f), (ex, "moe_in", fa)),
        "w_down": P((E, f, d), (ex, fa, "moe_out")),
    }
    if m.n_shared_experts:
        s["shared"] = layers.mlp_spec(d, m.n_shared_experts * f)
    return s


def capacity(tokens_per_group: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(2.0 * tokens_per_group * m.top_k / m.n_experts) + 1
    return max(4, -(-c // 4) * 4)  # round up to multiple of 4


def _slots_one_group(idx: jax.Array, E: int, C: int) -> Tuple[jax.Array,
                                                              jax.Array]:
    """idx: (T, k) expert assignments -> (slot (Tk,), keep (Tk,)).

    slot ∈ [0, E·C) for kept assignments, E·C (overflow row) for drops;
    rank-within-expert in token order is the drop priority."""
    T, k = idx.shape
    flat_e = idx.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (Tk, E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    rank = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = rank < C
    slot = jnp.where(keep, flat_e * C + rank, E * C)
    return slot, keep


def _dense_all_experts(p: Dict, cfg: ArchConfig, xg: jax.Array,
                       gates: jax.Array, idx: jax.Array) -> jax.Array:
    """Small-E mode (grok: top-2 of 8): compute EVERY expert on every token
    and mix by dense gates.  Trades E/top_k FLOP overcompute for dispatch-
    free communication: the only collective is the token-space partial-sum
    all-reduce of the fused (E·f → d) contraction — slot buffers, scatters
    and their partitioner-hostile gathers disappear entirely."""
    m = cfg.moe
    G, T, d = xg.shape
    E = m.n_experts
    gates_dense = jnp.zeros((G, T, E), xg.dtype).at[
        jnp.arange(G)[:, None, None],
        jnp.arange(T)[None, :, None], idx].set(gates.astype(xg.dtype))
    g = _edot("gtd,edf->gtef", xg, p["w_gate"], F32)
    u = _edot("gtd,edf->gtef", xg, p["w_up"], F32)
    h = (jax.nn.silu(g) * u).astype(xg.dtype)
    # fold the gates into h FIRST (elementwise), then contract E and f in a
    # single dot -> the partial-sum AR is token-space (G,T,d).  A 3-operand
    # einsum here lets XLA contract f before e, all-reducing an E×-larger
    # (E,d,G,T) intermediate (measured: 3.1 TB/step on grok).
    h = h * gates_dense[..., None]
    return _edot("gtef,efd->gtd", h, p["w_down"],
                 layers.reduce_dtype()).astype(xg.dtype)


def _slot_dispatch(p: Dict, cfg: ArchConfig, xg: jax.Array, gates: jax.Array,
                   idx: jax.Array, C: int, ep_model: bool) -> jax.Array:
    m = cfg.moe
    G, T, d = xg.shape
    E, k = m.n_experts, m.top_k
    slot, keep = jax.vmap(functools.partial(_slots_one_group, E=E, C=C))(idx)
    row = E * C + 1                                           # +overflow row
    gslot = (jnp.arange(G)[:, None] * row + slot).reshape(-1)  # (G·Tk,)
    xs = jnp.repeat(xg, k, axis=1).reshape(G * T * k, d)
    buf = jnp.zeros((G * row, d), xg.dtype).at[gslot].add(xs)
    buf = buf.reshape(G, row, d)[:, :E * C].reshape(G, E, C, d)

    if ep_model:   # reshard: groups stay on data, experts go to model (a2a)
        buf = shlib.constrain_act(buf, ("batch", "experts_mdl", None, None))

    g = _edot("gecd,edf->gecf", buf, p["w_gate"], F32)
    u = _edot("gecd,edf->gecf", buf, p["w_up"], F32)
    h = (jax.nn.silu(g) * u).astype(xg.dtype)
    y = _edot("gecf,efd->gecd", h, p["w_down"],
              layers.reduce_dtype()).astype(xg.dtype)

    if ep_model:   # back to group-parallel for the combine (reverse a2a)
        y = shlib.constrain_act(y, ("batch", None, None, None))

    y_flat = jnp.concatenate(
        [y.reshape(G, E * C, d), jnp.zeros((G, 1, d), xg.dtype)], axis=1)
    y_tok = jnp.take_along_axis(
        y_flat, slot.reshape(G, T * k)[..., None], axis=1)
    y_tok = y_tok * (gates.reshape(G, T * k, 1).astype(xg.dtype)
                     * keep.reshape(G, T * k, 1))
    return y_tok.reshape(G, T, k, d).sum(axis=2)


def apply(p: Dict, cfg: ArchConfig, x: jax.Array
          ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: (B, S, d) -> (y, aux).  Groups = sequences (or the whole batch for
    single-token decode) so dispatch stays local under data sharding."""
    m = cfg.moe
    mode = moe_mode(cfg)
    B, S, d = x.shape
    xg = x.reshape(1, B, d) if S == 1 else x
    G, T, _ = xg.shape
    E, k = m.n_experts, m.top_k
    C = capacity(T, cfg)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"],
                        preferred_element_type=F32)          # (G,T,E) f32
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # (G,T,k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)

    if mode == "dense":
        out = _dense_all_experts(p, cfg, xg, gates, idx)
    else:
        out = _slot_dispatch(p, cfg, xg, gates, idx, C,
                             ep_model=(mode == "ep_model"))
    if S == 1:
        out = out.reshape(B, S, d)

    if m.n_shared_experts:
        out = out + layers.mlp(p["shared"], x)

    # auxiliary losses (Switch-style load balance + router z-loss)
    me = probs.mean(axis=(0, 1))                             # (E,)
    ce = (jax.nn.one_hot(idx, E, dtype=F32)
          .sum(axis=2).mean(axis=(0, 1)))                    # frac tokens/e
    aux = {
        "moe_lb_loss": E * jnp.sum(me * ce) * m.aux_loss_coef,
        "moe_z_loss": (jax.nn.logsumexp(logits, axis=-1) ** 2).mean()
        * m.router_z_coef,
    }
    return out, aux
