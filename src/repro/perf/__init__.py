"""``repro.perf`` — train-step performance subsystem.

The ROADMAP north-star's "make a hot path measurably faster" axis applied
to *training*: the RL update's backward otherwise stores full backbone
activations for every denoising step, each ``BaseTrainer.step`` dispatches
three separate jits, and the rollout body pays for both the SDE and ODE
branches even for statically pure-ODE trainers.  Everything here is driven
by :class:`repro.config.PerfConfig` (``--set perf.*`` from every front
door) and is a *runtime* choice — checkpoints move freely across policies.

* ``policy``   — PerfConfig validation, remat helpers, activation dtype
* ``fused``    — the single-jit sample→rewards→advantages→update step
* ``memory``   — ``compiled.memory_analysis()`` introspection
* ``offload``  — host-memory offload: reward towers + remat residuals

Exactness contract (asserted in tests/test_perf.py / test_pipeline.py):

* ``remat="scan"``  : bit-identical to ``"none"`` on XLA:CPU — a
  ``jax.checkpoint`` around a ``lax.scan`` body is structurally isolated,
  so the recompute graph matches the original exactly.
* ``remat="block"`` : f32-rounding-equal (rtol 1e-5 / atol 1e-6) — XLA
  re-fuses open-graph remat and reassociates f32 reductions.
* ``fuse_step``     : f32-rounding-equal to the three-jit path (same ops,
  different compiled program).
* ``offload_rewards`` : f32-rounding-equal — reward params arrive as jit
  *arguments* instead of baked-in constants, a different compiled program.
* ``remat_offload``   : f32-rounding-equal — saved-to-host residuals
  replace recompute in the scan backward.
"""
from repro.perf.fused import make_fused_step
from repro.perf.memory import analysis_dict, update_memory
from repro.perf.offload import (offload_param_store, prefetch_tree,
                                reward_tower_report, tree_bytes)
from repro.perf.policy import (REMAT_MODES, block_remat, remat_policy,
                               resolve_policy_dtype, validate)

__all__ = [
    "REMAT_MODES", "block_remat", "remat_policy", "resolve_policy_dtype",
    "validate", "make_fused_step", "analysis_dict", "update_memory",
    "offload_param_store", "prefetch_tree", "reward_tower_report",
    "tree_bytes",
]
