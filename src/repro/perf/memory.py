"""Peak-memory introspection for the train step via XLA's
``compiled.memory_analysis()``.

The remat policy trades recompute for activation memory; this module makes
the trade observable without running anything — the update is AOT-lowered
on ``ShapeDtypeStruct``s and compiled, and the analysis byte counts are
returned (``temp`` is the interesting one: scratch + activation buffers,
where the loss backward's per-step residuals live).  Used by
``BaseTrainer.memory_stats``, the ``perf.log_memory`` launcher line, the
``benchmarks/train_step.py`` trajectory, and the tests/test_perf.py
regression that peak temp bytes strictly drop under ``remat="scan"``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.rollout import Trajectory

F32 = jnp.float32

_FIELDS = {
    "temp_bytes": "temp_size_in_bytes",
    "argument_bytes": "argument_size_in_bytes",
    "output_bytes": "output_size_in_bytes",
    "peak_bytes": "peak_memory_in_bytes",
    "generated_code_bytes": "generated_code_size_in_bytes",
}


def analysis_dict(compiled) -> Dict[str, Optional[int]]:
    """``memory_analysis()`` as a plain dict (None where the backend does
    not implement a field — CPU reports temp/argument/output)."""
    try:
        mem = compiled.memory_analysis()
    except Exception as e:                 # backend without analysis support
        return {"error": str(e)}
    return {k: getattr(mem, attr, None) for k, attr in _FIELDS.items()}


def _struct(tree: Any) -> Any:
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)),
        tree)


def state_bytes(trainer) -> Dict[str, int]:
    """Param + optimizer byte footprint: the canonical (unsharded) total
    and what one device actually holds under the trainer's active
    :class:`repro.distributed.PartitionPlan` — equal when nothing is
    sharded (no mesh, or ``model_parallel=1``), strictly smaller per
    device under an FSDP/expert/head-sharded plan.  Host-side arithmetic
    over shapes; nothing compiles or runs."""
    plan = getattr(trainer, "plan", None)
    if plan is not None:
        return plan.bytes_report(trainer.state)
    total = 0
    for leaf in jax.tree.leaves(trainer.state):
        size = 1
        for d in jnp.shape(leaf):
            size *= int(d)
        total += size * jnp.dtype(jnp.result_type(leaf)).itemsize
    return {"total_bytes": int(total), "per_device_bytes": int(total),
            "sharded_leaves": 0}


def update_memory(trainer, cond: jax.Array) -> Dict[str, Dict]:
    """AOT-compile the trainer's jitted update — and, when
    ``perf.fuse_step`` is on, the fused step — for a ``cond`` prompt batch
    of shape (P, Lc, cond_dim), and report the analysis byte counts.

    Pure introspection: nothing executes and no live buffer is touched
    (lowering on structs never donates real state)."""
    f = trainer.flow
    P, Lc, D = cond.shape
    B = P * f.group_size
    T = f.num_steps
    traj = Trajectory(
        xs=jax.ShapeDtypeStruct((T + 1, B, f.latent_tokens, f.latent_dim),
                                F32),
        logps=jax.ShapeDtypeStruct((T, B), F32),
        ts=jax.ShapeDtypeStruct((T + 1,), F32),
        sde_mask=jax.ShapeDtypeStruct((T,), jnp.bool_),
        cond=jax.ShapeDtypeStruct((B, Lc, D), F32),
    )
    adv = jax.ShapeDtypeStruct((B,), F32)
    key = _struct(jax.random.PRNGKey(0))
    state = _struct(trainer.state)
    extras = _struct(trainer.update_extras())
    from repro.perf.offload import reward_tower_report
    out = {"update": analysis_dict(
        trainer._update_jit.lower(state, traj, adv, key, extras).compile()),
        "state": state_bytes(trainer),
        # the frozen-tower footprint and what perf.offload_rewards frees
        # from the device (host-side shape arithmetic, nothing compiles)
        "reward_towers": reward_tower_report(trainer)}
    if trainer._fused_jit is not None:
        cond_g = jax.ShapeDtypeStruct((B, Lc, D), F32)
        it = jax.ShapeDtypeStruct((), jnp.int32)
        mask = jax.ShapeDtypeStruct((T,), jnp.bool_)
        fused_args = [state, cond_g, key, it, mask, extras]
        if trainer.offloads_rewards:
            fused_args.append(_struct(trainer._reward_store_host))
        out["fused"] = analysis_dict(trainer._fused_jit.lower(
            *fused_args).compile())
    return out
