"""The fused train step: sample→rewards→advantages→update in ONE jit.

``BaseTrainer.step`` otherwise dispatches three separate jits per
iteration (sample, rewards, update), paying Python dispatch and jit
boundary costs — every intermediate (the full stacked trajectory) must be
materialized as a jit output just to be fed straight back in.  Fusing the
phases into one donated jit removes those boundaries: XLA sees the whole
step, dead-code-eliminates trajectory buffers nobody reads (the pure-ODE
NFT/AWM losses touch only ``x0``, so the (T+1, B, Lt, ld) stack and the
log-prob buffers vanish entirely), and the step's metrics — including the
weighted ``reward_mean`` — come back as device scalars in the same
dispatch.

Numerics: the trajectory is ``stop_gradient``-ed before the loss, exactly
matching the unfused path where it crosses a jit boundary as data (the
GRPO estimator treats samples as drawn from the behaviour policy).  The
fused and unfused steps run the same ops but compile as different
programs, so they are f32-rounding-equal, not bit-identical
(tests/test_perf.py asserts the documented tolerances).
"""
from __future__ import annotations

import jax

from repro import distributed


def make_fused_step(trainer):
    """Build the fused step for ``trainer``; returns the jitted
    ``fn(state, cond_g, key, it, sde_mask, extras) -> (state, metrics)``.

    ``cond_g`` is the group-repeated (B, Lc, cond_dim) batch — repetition
    and the divisibility check stay host-side so the sharded layout
    matches the unfused entry points.  ``key``/``it`` are the raw loop key
    and iteration index; the per-iteration fold + split happens on device
    (``it`` is a traced scalar, so iterating never recompiles).

    With ``perf.offload_rewards`` the fused step takes the host-offloaded
    reward-tower store as a trailing argument (threaded by
    ``BaseTrainer.step`` from the loop's prefetch) — never a closure, which
    would re-bake the towers in as device-resident constants and undo the
    offload."""
    group_size = trainer.flow.group_size
    offloaded = trainer.offloads_rewards

    def _step(state, cond_g, key, it, sde_mask, extras, reward_params):
        k_s, k_u = jax.random.split(jax.random.fold_in(key, it))
        traj = trainer._sample(state.params, cond_g, k_s, sde_mask)
        # samples are data from the behaviour policy: the unfused path gets
        # this for free at the sample-jit boundary, here it must be explicit
        # (the rollout is differentiable w.r.t. params otherwise)
        traj = jax.tree.map(jax.lax.stop_gradient, traj)
        _, adv, reward_stats = trainer._rewards(
            traj.x0, {"cond": traj.cond}, reward_params,
            group_size=group_size)
        new_state, metrics = trainer._update(state, traj, adv, k_u, extras)
        metrics.update(reward_stats)
        return new_state, metrics

    if offloaded:
        def fused(state, cond_g, key, it, sde_mask, extras, reward_params):
            return _step(state, cond_g, key, it, sde_mask, extras,
                         reward_params)
    else:
        def fused(state, cond_g, key, it, sde_mask, extras):
            return _step(state, cond_g, key, it, sde_mask, extras, None)

    donate = trainer.dist.donate_state and trainer.donate_state_ok
    return distributed.jit_fused_step(
        fused, trainer.mesh, getattr(trainer, "state_sharding", None),
        donate=donate, extras_sharding=trainer.update_extras_sharding(),
        with_reward_params=offloaded)
