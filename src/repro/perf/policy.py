"""PerfConfig validation and the remat / dtype policy helpers.

The remat policy has exactly three values because they map onto the three
distinct exactness classes ``jax.checkpoint`` exhibits on this codebase
(see the package docstring): no remat, scan-body remat (exact), and
per-layer block remat inside the backbone (rounding-equal).  The
scan-body primitive itself is ``core.rollout.checkpoint_scan_body`` —
core cannot import this package.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import PerfConfig

REMAT_MODES = ("none", "scan", "block")

POLICY_DTYPES = {
    "": None,                     # inherit the parameter dtype
    "bfloat16": jnp.bfloat16,
    "float32": jnp.float32,
}


def validate(perf: PerfConfig) -> PerfConfig:
    """Fail construction-time on unknown knob values (a typo'd ``--set
    perf.remat=blocks`` must not silently train without remat)."""
    if perf.remat not in REMAT_MODES:
        raise ValueError(
            f"perf.remat must be one of {REMAT_MODES}, got {perf.remat!r}")
    if perf.policy_dtype not in POLICY_DTYPES:
        raise ValueError(
            f"perf.policy_dtype must be one of "
            f"{sorted(POLICY_DTYPES)}, got {perf.policy_dtype!r}")
    if perf.remat_offload and perf.remat != "scan":
        raise ValueError(
            "perf.remat_offload saves the scan body's named residuals to "
            "host memory and only composes with the scan-body checkpoint "
            f"— set perf.remat=scan (got remat={perf.remat!r})")
    return perf


def remat_policy(perf: PerfConfig):
    """The ``jax.checkpoint`` policy for the scan-body remat, or None.
    Only ``perf.remat_offload`` sets one (host-offload the named velocity
    residual instead of recomputing it — ``repro.perf.offload``); plain
    ``remat="scan"`` stays policy-free, preserving its bit-identical
    exactness class."""
    if not perf.remat_offload:
        return None
    from repro.perf.offload import remat_offload_policy
    return remat_offload_policy()


def resolve_policy_dtype(perf: PerfConfig):
    """The activation compute dtype for the velocity field, or ``None`` to
    inherit the parameter dtype (log-probs/optimizer stay f32 regardless)."""
    return POLICY_DTYPES[perf.policy_dtype]


def block_remat(remat: str) -> bool:
    """Whether the backbone's per-layer block remat should be threaded
    through ``FlowAdapter.velocity``."""
    return remat == "block"
