"""Host memory offload for the train step (``perf.offload_rewards`` /
``perf.remat_offload``).

Two independent mechanisms, one idea — device HBM should hold what the
*current* computation needs, not everything that is frozen:

* **Reward towers** (``offload_rewards``): the frozen reward-model params
  are needed only during the (cheap) reward phase of each step, yet the
  historical path kept them device-resident for the whole run — worse,
  closure-captured inside the rewards jit as trace-time constants.
  :func:`offload_param_store` parks them in host memory; the trainer then
  threads them into the rewards/fused jit as *arguments* (never closures —
  the PR-2 constant-capture class, jaxlint R003) and the TrainLoop starts
  the H2D copy right after each dispatch (:func:`prefetch_tree`), so the
  transfer overlaps the in-flight step's rollout+backward.  Exactness:
  f32-rounding-equal to the resident path (same ops, but arguments compile
  a different program than baked-in constants).

* **Remat residuals** (``remat_offload``): ``remat="scan"`` recomputes the
  scan body in the backward; :func:`remat_offload_policy` builds the
  ``jax.checkpoint_policies.save_and_offload_only_these_names`` policy
  that instead *saves* the named velocity residual to host memory and
  reloads it in the backward — trading recompute for PCIe traffic.  The
  named residuals are tagged in ``repro.core.rollout`` / the GRPO loss
  scan via ``jax.ad_checkpoint.checkpoint_name``.

Backend notes: memory *kinds* are how XLA addresses host memory from
within a compiled program.  Accelerator backends expose ``pinned_host``
alongside the device default; the CPU backend's default memory already
*is* the host (``unpinned_host`` is its only kind), so
:func:`host_memory_kind` returns None there and :func:`offload_param_store`
degrades to plain ``device_get`` numpy arrays — same semantics, and the
"device" bytes accounted in :func:`reward_tower_report` are what an
accelerator run would free.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

# preference order: pinned host memory DMAs back to device without a
# staging copy; unpinned is still off-HBM
_HOST_KINDS = ("pinned_host", "unpinned_host")

#: residual names the remat-offload policy saves to host (tagged with
#: ``checkpoint_name`` in the rollout / GRPO-loss scan bodies)
OFFLOAD_NAMES = ("velocity",)


def host_memory_kind(device=None) -> Optional[str]:
    """A host memory kind addressable by ``device`` and distinct from its
    default memory, or None when the default already lives on the host
    (XLA:CPU) or the backend predates memory kinds."""
    if device is None:
        device = jax.local_devices()[0]
    try:
        kinds = {m.kind for m in device.addressable_memories()}
        default = device.default_memory().kind
    except Exception:                    # backend without memory-kind API
        return None
    for kind in _HOST_KINDS:
        if kind in kinds and kind != default:
            return kind
    return None


def tree_bytes(tree: Any) -> int:
    """Total byte footprint of a pytree's leaves (host-side arithmetic
    over shapes — nothing is fetched or compiled)."""
    total = 0
    for leaf in jax.tree.leaves(tree):
        size = 1
        for d in jnp.shape(leaf):
            size *= int(d)
        total += size * jnp.dtype(jnp.result_type(leaf)).itemsize
    return int(total)


def offload_tree(tree: Any) -> Any:
    """Move a pytree to host memory.  On backends with a distinct host
    memory kind the leaves stay jax arrays under a host-kind sharding
    (so :func:`prefetch_tree` is a pure memory-kind transfer); on CPU the
    leaves become numpy arrays via one ``device_get``."""
    kind = host_memory_kind()
    if kind is None:
        return jax.device_get(tree)
    sharding = jax.sharding.SingleDeviceSharding(jax.local_devices()[0],
                                                 memory_kind=kind)
    return jax.device_put(tree, sharding)


def prefetch_tree(host_tree: Any, sharding=None) -> Any:
    """Start the async H2D copy of a host-offloaded pytree and return the
    device arrays immediately (``jax.device_put`` enqueues; the transfer
    overlaps whatever device work is already in flight).  ``sharding``
    replicates the tree over a mesh when the trainer has one."""
    if sharding is None:
        return jax.device_put(host_tree)
    return jax.device_put(host_tree, sharding)


def offload_param_store(loader) -> Dict[str, Any]:
    """Park a :class:`~repro.core.rewards.MultiRewardLoader`'s param store
    in host memory and rebase the loader onto the host copies.  Returns
    the host store the trainer threads into the rewards jit.  Rebasing
    keeps any accidental closure capture *correct* (the values are the
    same) — it would merely forfeit the memory win, and jaxlint R003
    polices that capture anyway."""
    host = {mid: offload_tree(p) for mid, p in loader.param_store().items()}
    loader.rebase(host)
    return host


def reward_tower_report(trainer) -> Dict[str, Any]:
    """The ``perf.log_memory`` accounting entry for the reward towers:
    their total byte footprint, what stays device-resident under the
    active policy, and the device bytes ``offload_rewards`` freed."""
    total = tree_bytes(trainer.loader.param_store())
    off = trainer.offloads_rewards
    return {
        "tower_bytes": total,
        "device_resident_bytes": 0 if off else total,
        "device_bytes_freed": total if off else 0,
        "offloaded": off,
    }


def remat_offload_policy():
    """The ``jax.checkpoint`` policy for ``perf.remat_offload``: save the
    :data:`OFFLOAD_NAMES` residuals to host memory instead of recomputing
    them in the scan backward; everything unnamed is still rematerialized.
    Returns None when this jax predates named offload policies (the knob
    then degrades to plain ``remat="scan"``)."""
    try:
        make = jax.checkpoint_policies.save_and_offload_only_these_names
    except AttributeError:               # pragma: no cover - old jax
        return None
    try:
        return make(names_which_can_be_saved=[],
                    names_which_can_be_offloaded=list(OFFLOAD_NAMES),
                    offload_src="device", offload_dst="pinned_host")
    except TypeError:                    # pragma: no cover - API drift
        return None
