"""Pure-jnp oracles for every Pallas kernel (the correctness contract the
shape/dtype sweep tests assert against)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

F32 = jnp.float32
NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0
                        ) -> jax.Array:
    """q: (B,Sq,H,D); k/v: (B,Sk,K,D/Dv) -> (B,Sq,H,Dv)."""
    B, Sq, H, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, Sq, K, G, D).astype(F32)
    s = jnp.einsum("bskgd,btkd->bskgt", qg, k.astype(F32)) * (D ** -0.5)
    qp = jnp.arange(Sq)[:, None]
    kp = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    s = jnp.where(ok[None, :, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bskgt,btkd->bskgd", p, v.astype(F32))
    return o.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def ssd_scan_ref(x, dt, a, bm, cm, *, init_state=None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Sequential (non-chunked) SSD recurrence — the ground truth.

    x: (B,L,H,P); dt: (B,L,H); a: (H,); bm/cm: (B,L,N).
    Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    B, L, H, P = x.shape
    N = bm.shape[-1]
    h0 = (jnp.zeros((B, H, P, N), F32) if init_state is None
          else init_state.astype(F32))

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp           # (B,H,P),(B,H),(B,N),(B,N)
        dA = jnp.exp(dt_t.astype(F32) * a.astype(F32))          # (B,H)
        h = h * dA[..., None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt_t.astype(F32), b_t.astype(F32),
            x_t.astype(F32))
        y = jnp.einsum("bhpn,bn->bhp", h, c_t.astype(F32))
        return h, y

    hT, ys = jax.lax.scan(step, h0,
                          (x.swapaxes(0, 1), dt.swapaxes(0, 1),
                           bm.swapaxes(0, 1), cm.swapaxes(0, 1)))
    return ys.swapaxes(0, 1).astype(x.dtype), hT


def sde_step_ref(v, x, t, t_next, eps, *, eta: float
                 ) -> Tuple[jax.Array, jax.Array]:
    """Flow-SDE Euler–Maruyama step + Gaussian log-prob (paper Eq. 1).

    v, x, eps: (B, ...); t, t_next: scalars.  Returns (x_next, logp (B,))."""
    xf, vf = x.astype(F32), v.astype(F32)
    # σ argument clamped (FlowSDEScheduler.t_sigma_max); drift uses raw t
    tc = jnp.clip(t, 1e-4, 0.96)
    sigma = eta * jnp.sqrt(tc / (1.0 - tc))
    delta = t - t_next
    drift = vf + (sigma ** 2 / (2.0 * t)) * (xf + (1.0 - t) * vf)
    mean = xf - drift * delta
    std = sigma * jnp.sqrt(delta)
    x_next = mean + std * eps.astype(F32)
    z = (x_next - mean) / std
    logp = (-0.5 * (z * z + jnp.log(2.0 * jnp.pi)) - jnp.log(std))
    return x_next, logp.reshape(x.shape[0], -1).sum(-1)


def grpo_loss_ref(logp_new, logp_old, adv, *, clip: float,
                  guard: bool = False) -> Tuple[jax.Array, jax.Array]:
    """PPO-clip objective per sample (optionally GRPO-Guard RatioNorm).

    logp_new/logp_old/adv: (B,). Returns (per-sample loss, clip fraction)."""
    ratio = jnp.exp(jnp.clip(logp_new - logp_old, -20.0, 20.0))
    if guard:
        ratio = ratio / jnp.maximum(
            jax.lax.stop_gradient(ratio.mean()), 1e-6)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
    loss = -jnp.minimum(unclipped, clipped)
    frac = (jnp.abs(ratio - 1.0) > clip).astype(F32)
    return loss, frac
