"""Mamba2 SSD chunked-scan Pallas kernel (TPU target).

Grid: (B, H, n_chunks) — the chunk dimension is innermost, i.e. sequential on
TPU, so the recurrent state lives in a VMEM scratch carried across chunk
iterations.  Per chunk the kernel evaluates the SSD dual form:

  y_diag = (exp(segsum(dA)) ⊙ (C·Bᵀ)) · (dt ⊙ x)      intra-chunk, quadratic
  y_off  = exp(cum dA) ⊙ (C · h_prevᵀ)                 carried state
  h_new  = exp(Σ dA)·h_prev + (decay-to-end ⊙ dt ⊙ x)ᵀ · B

VMEM working set at Q=128, P=64, N=128:
  x(128×64) + b/c(128×128) + att(128×128) + state(64×128) f32 ≈ 0.3 MB.
MXU-aligned matmul dims (Q=128, N=128); P is the lane dim of y.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hT_ref, h_scr, *,
                n_chunks: int, Q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(F32)         # (Q, P)
    dt = dt_ref[0, :, 0].astype(F32)          # (Q,)
    a = a_ref[0].astype(F32)                  # ()
    bm = b_ref[0].astype(F32)                 # (Q, N)
    cm = c_ref[0].astype(F32)                 # (Q, N)

    dA = dt * a                               # (Q,)
    cum = jnp.cumsum(dA)                      # (Q,)
    total = cum[-1]

    # intra-chunk dual form
    seg = cum[:, None] - cum[None, :]         # (Q, Q): sum_{j+1..i}
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    decay = jnp.where(mask, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=F32)   # (Q, Q)
    att = decay * scores
    xdt = x * dt[:, None]                     # (Q, P)
    y = jax.lax.dot_general(att, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=F32)        # (Q, P)

    # carried-state contribution
    h_prev = h_scr[...]                       # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cm, h_prev, (((1,), (1,)), ((), ())),
        preferred_element_type=F32)           # (Q,N)·(P,N)ᵀ -> (Q,P)

    # state update
    w = jnp.exp(total - cum)[:, None] * xdt   # (Q, P)
    h_scr[...] = jnp.exp(total) * h_prev + jax.lax.dot_general(
        w, bm, (((0,), (0,)), ((), ())),
        preferred_element_type=F32)           # (P, N)

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _final():
        hT_ref[0, 0] = h_scr[...].astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, bm: jax.Array,
             cm: jax.Array, *, chunk: int = 128, interpret: bool = False):
    """x: (B,L,H,P); dt: (B,L,H); a: (H,); bm/cm: (B,L,N).

    Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    B, L, H, P = x.shape
    N = bm.shape[-1]
    Q = min(chunk, L)
    assert L % Q == 0
    nc = L // Q

    kernel = functools.partial(_ssd_kernel, n_chunks=nc, Q=Q)
    y, hT = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, L, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), F32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), F32)],
        interpret=interpret,
    )(x, dt, a, bm, cm)
    return y, hT
