"""Fused Flow-SDE sampling step (paper Eq. 1) — Pallas kernel (TPU target).

The RL sampling loop applies this elementwise update T times per trajectory;
it is bandwidth-bound (5 streams: v, x, ε in; x_next, logp out), so fusing
drift + noise injection + Gaussian log-density + the per-sample reduction
into one VMEM pass removes three HBM round-trips vs. the unfused XLA graph.

Grid: one program per batch row; block = the full flattened latent (Lt·ld ≈
16 K floats ≈ 64 KB — VMEM-trivial).  The log-prob reduction happens in-
register before the single (B,) output write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
LOG2PI = 1.8378770664093453


def _sde_kernel(v_ref, x_ref, eps_ref, t_ref, tn_ref, xn_ref, lp_ref, *,
                eta: float):
    t = t_ref[0]
    t_next = tn_ref[0]
    # σ argument clamped (FlowSDEScheduler.t_sigma_max); drift uses raw t —
    # identical numerics to the jnp scheduler path (asserted in tests)
    tc = jnp.clip(t, 1e-4, 0.96)
    sigma2 = eta * eta * tc / (1.0 - tc)
    sigma = jnp.sqrt(sigma2)
    delta = t - t_next
    std = sigma * jnp.sqrt(delta)

    v = v_ref[...].astype(F32)
    x = x_ref[...].astype(F32)
    eps = eps_ref[...].astype(F32)

    drift = v + (sigma2 / (2.0 * t)) * (x + (1.0 - t) * v)
    mean = x - drift * delta
    x_next = mean + std * eps
    xn_ref[...] = x_next.astype(xn_ref.dtype)
    # z = (x_next-mean)/std = eps exactly -> fused logpdf
    lp = -0.5 * (eps * eps + LOG2PI) - jnp.log(std)
    lp_ref[0] = jnp.sum(lp)


@functools.partial(jax.jit, static_argnames=("eta", "interpret"))
def sde_step(v: jax.Array, x: jax.Array, eps: jax.Array, t: jax.Array,
             t_next: jax.Array, *, eta: float = 0.7,
             interpret: bool = False):
    """v, x, eps: (B, ...); t/t_next scalar f32. Returns (x_next, logp (B,))."""
    B = x.shape[0]
    feat = int(x.size // B)
    vf = v.reshape(B, feat)
    xf = x.reshape(B, feat)
    ef = eps.reshape(B, feat)
    tb = jnp.broadcast_to(jnp.asarray(t, F32), (1,))
    tnb = jnp.broadcast_to(jnp.asarray(t_next, F32), (1,))

    kernel = functools.partial(_sde_kernel, eta=eta)
    x_next, logp = pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, feat), lambda b: (b, 0)),
            pl.BlockSpec((1, feat), lambda b: (b, 0)),
            pl.BlockSpec((1, feat), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (0,)),
            pl.BlockSpec((1,), lambda b: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, feat), lambda b: (b, 0)),
            pl.BlockSpec((1,), lambda b: (b,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, feat), F32),
            jax.ShapeDtypeStruct((B,), F32),
        ],
        interpret=interpret,
    )(vf, xf, ef, tb, tnb)
    return x_next.reshape(x.shape), logp
