"""Kernel dispatch layer.

The model/trainer code calls these wrappers; they route to the Pallas kernel
on TPU (or in interpret mode when REPRO_PALLAS=interpret — the CPU CI
configuration) and to the pure-jnp reference otherwise.  This keeps the
XLA-path HLO (what the CPU dry-run lowers) and the kernel path behaviourally
identical — the tests assert exactly that.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.grpo_loss import grpo_loss as _grpo
from repro.kernels.sde_step import sde_step as _sde
from repro.kernels.ssd_scan import ssd_scan as _ssd


def _mode() -> str:
    env = os.environ.get("REPRO_PALLAS", "auto")
    if env in ("interpret", "off", "on"):
        return env
    return "on" if jax.default_backend() == "tpu" else "off"


def pallas_enabled() -> bool:
    return _mode() in ("on", "interpret")


def _interpret() -> bool:
    return _mode() == "interpret"


def flash_attention(q, k, v, *, causal=True, window=0):
    if pallas_enabled():
        return _flash(q, k, v, causal=causal, window=window,
                      interpret=_interpret())
    return ref.flash_attention_ref(q, k, v, causal=causal, window=window)


def ssd_scan(x, dt, a, bm, cm, *, chunk=128):
    if pallas_enabled():
        return _ssd(x, dt, a, bm, cm, chunk=chunk, interpret=_interpret())
    return ref.ssd_scan_ref(x, dt, a, bm, cm)


def sde_step(v, x, eps, t, t_next, *, eta=0.7):
    if pallas_enabled():
        return _sde(v, x, eps, t, t_next, eta=eta, interpret=_interpret())
    return ref.sde_step_ref(v, x, t, t_next, eps, eta=eta)


def grpo_loss(logp_new, logp_old, adv, ratio_mean=None, *, clip=0.2,
              guard=False):
    if pallas_enabled():
        return _grpo(logp_new, logp_old, adv, ratio_mean, clip=clip,
                     guard=guard, interpret=_interpret())
    return ref.grpo_loss_ref(logp_new, logp_old, adv, clip=clip, guard=guard)


def grpo_loss_trainable(logp_new, logp_old, adv, *, clip=0.2):
    """Differentiable GRPO loss for the trainer: fused-kernel forward with
    the closed-form PPO-clip VJP (see kernels/grpo_loss.py); clip-fraction
    metric computed alongside (non-differentiated)."""
    if pallas_enabled():
        from repro.kernels.grpo_loss import grpo_loss_diff
        loss = grpo_loss_diff(logp_new, logp_old, adv, clip, _interpret())
        ratio = jnp.exp(jnp.clip(jax.lax.stop_gradient(logp_new - logp_old),
                                 -20.0, 20.0))
        frac = (jnp.abs(ratio - 1.0) > clip).astype(jnp.float32)
        return loss, frac
    return ref.grpo_loss_ref(logp_new, logp_old, adv, clip=clip, guard=False)
