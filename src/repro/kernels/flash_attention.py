"""Flash attention Pallas kernel (TPU target).

Blocked online-softmax attention with GQA, causal and sliding-window masks.
Tiling: q blocks × kv blocks, both 128 (MXU-aligned); running max / sum /
output accumulator live in VMEM scratch across the (sequential) kv grid
dimension.  Per-block VMEM working set at D=128:
  q(128×128) + k(128×128) + v(128×128) + acc(128×128) f32 + stats ≈ 0.4 MB —
comfortably double-bufferable against the ~128 MB v5e VMEM budget.

Causal block skipping: kv blocks strictly above the diagonal contribute
nothing; the kernel masks them and — because the kv index is the innermost
grid dimension — XLA's Mosaic pipeline still fetches them, so the *kernel*
cost model counts only the ~half blocks that pass the mask (see
launch/costs.py ``attn_flops_kernel``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int,
                 block_q: int, block_k: int, n_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (Bq, D)
    k = k_ref[0].astype(jnp.float32)                    # (Bk, D)
    v = v_ref[0].astype(jnp.float32)                    # (Bk, Dv)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    ok = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]                                 # (Bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                              # (Bq, Bk)
    alpha = jnp.exp(m_prev - m_new)                     # (Bq, 1)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == n_kv_blocks - 1)
    def _final():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Sk, K, D) with H % K == 0.

    Returns (B, Sq, H, Dv).  Sq must divide block_q, Sk by block_k (callers
    pad); positions are 0-based on both sides (self-attention layout).
    """
    B, Sq, H, D = q.shape
    Sk, K, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // K
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    n_q = Sq // block_q
    n_k = Sk // block_k
    scale = D ** -0.5

    # fold heads into the leading grid dim: (B*H, Sq, D)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, Dv)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_kv_blocks=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, D),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, Dv),
                         lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, Dv),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3)
