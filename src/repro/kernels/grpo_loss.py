"""Fused GRPO policy-gradient loss — Pallas kernel (TPU target).

Fuses ratio computation, (optional GRPO-Guard RatioNorm), PPO clipping and
the advantage product into one pass over the (T·B,) per-transition arrays.
Block = 1024 rows (padded); a second tiny pass is unnecessary because the
Guard mean is supplied by the caller (it is a batch statistic computed once
per timestep, stop-gradient — see trainers/grpo_guard.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32
BLOCK = 1024


def _grpo_kernel(lpn_ref, lpo_ref, adv_ref, mean_ref, loss_ref, frac_ref, *,
                 clip: float, guard: bool):
    lpn = lpn_ref[...].astype(F32)
    lpo = lpo_ref[...].astype(F32)
    adv = adv_ref[...].astype(F32)
    ratio = jnp.exp(jnp.clip(lpn - lpo, -20.0, 20.0))
    if guard:
        ratio = ratio / jnp.maximum(mean_ref[0], 1e-6)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
    loss_ref[...] = -jnp.minimum(unclipped, clipped)
    frac_ref[...] = (jnp.abs(ratio - 1.0) > clip).astype(F32)


@functools.partial(jax.jit, static_argnames=("clip", "guard", "interpret"))
def grpo_loss(logp_new: jax.Array, logp_old: jax.Array, adv: jax.Array,
              ratio_mean: jax.Array | None = None, *, clip: float = 0.2,
              guard: bool = False, interpret: bool = False):
    """All inputs (B,). Returns (per-sample loss (B,), clip-fraction (B,))."""
    B = logp_new.shape[0]
    blk = min(BLOCK, B)
    pad = (-B) % blk
    def p(a):
        return jnp.pad(a.astype(F32), (0, pad))
    mean = (jnp.ones((1,), F32) if ratio_mean is None
            else jnp.broadcast_to(jnp.asarray(ratio_mean, F32), (1,)))
    n = (B + pad) // blk
    kernel = functools.partial(_grpo_kernel, clip=clip, guard=guard)
    loss, frac = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B + pad,), F32),
            jax.ShapeDtypeStruct((B + pad,), F32),
        ],
        interpret=interpret,
    )(p(logp_new), p(logp_old), p(adv), mean)
    return loss[:B], frac[:B]


# ---------------------------------------------------------------------------
# Differentiable wrapper: Pallas kernels carry no autodiff rule, but the
# PPO-clip gradient is closed-form:
#   ∂loss/∂logp_new = −A·ρ·𝟙[active]  with 𝟙[active] = 1 when the unclipped
#   branch is the min, else 1 only inside the clip band (where clip(ρ) moves).
# Forward runs the fused kernel; backward is elementwise jnp.
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def grpo_loss_diff(logp_new, logp_old, adv, clip: float = 0.2,
                   interpret: bool = False):
    loss, _ = grpo_loss(logp_new, logp_old, adv, None, clip=clip,
                        guard=False, interpret=interpret)
    return loss


def _gld_fwd(logp_new, logp_old, adv, clip, interpret):
    loss = grpo_loss_diff(logp_new, logp_old, adv, clip, interpret)
    return loss, (logp_new, logp_old, adv)


def _gld_bwd(clip, interpret, res, g):
    logp_new, logp_old, adv = res
    ratio = jnp.exp(jnp.clip(logp_new - logp_old, -20.0, 20.0))
    a = adv.astype(F32)
    unclipped = ratio * a
    clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * a
    in_band = (jnp.abs(ratio - 1.0) <= clip)
    active = jnp.where(unclipped <= clipped, True, in_band)
    gf = g.astype(F32)
    d_lpn = -a * ratio * active.astype(F32) * gf
    d_adv = -jnp.where(unclipped <= clipped, ratio,
                       jnp.clip(ratio, 1.0 - clip, 1.0 + clip)) * gf
    return d_lpn, -d_lpn, d_adv


grpo_loss_diff.defvjp(_gld_fwd, _gld_bwd)
