"""mamba2-370m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

Attention-free: n_heads/n_kv_heads/d_ff are 0 per the assignment; sequence
mixing is the chunked SSD scan, decode state is O(1) in context length (this
arch runs long_500k natively)."""
from repro.config import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm=SSMConfig(d_state=128, expand=2, head_dim=64, chunk=128,
                      d_conv=4),
        source="arXiv:2405.21060",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="mamba2-370m-reduced", family="ssm",
        n_layers=2, d_model=256, n_heads=0, n_kv_heads=0,
        d_ff=0, vocab_size=512,
        ssm=SSMConfig(d_state=32, expand=2, head_dim=32, chunk=32, d_conv=4),
        source="arXiv:2405.21060",
    )
