"""Architecture config registry: the 10 assigned architectures plus the
paper's own DiT family (``flux_dit``).

Each module exports ``config()`` (the exact assigned full-scale config) and
``reduced()`` (≤2 layers, d_model ≤ 512, ≤4 experts — used by CPU smoke
tests; the full configs are exercised only via the dry-run).

Every arch is also registered under the ``"arch"`` registry kind, so the
Experiment layer resolves backbones the same way it resolves trainers:
``registry.build("arch", "flux_dit", reduced=True)``.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro import registry
from repro.config import ArchConfig

ARCH_IDS = [
    "zamba2-2.7b",
    "grok-1-314b",
    "yi-34b",
    "internvl2-1b",
    "deepseek-v2-236b",
    "smollm-360m",
    "qwen3-32b",
    "yi-9b",
    "mamba2-370m",
    "musicgen-large",
]

PAPER_ARCHS = ["flux_dit"]

_MOD = {a: a.replace("-", "_").replace(".", "_") for a in
        ARCH_IDS + PAPER_ARCHS}


def _load(arch: str):
    if arch not in _MOD:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MOD)}")
    return importlib.import_module(f"repro.configs.{_MOD[arch]}")


def get(arch: str) -> ArchConfig:
    return _load(arch).config()


def get_reduced(arch: str) -> ArchConfig:
    return _load(arch).reduced()


def all_archs() -> List[str]:
    return list(ARCH_IDS)


def _arch_factory(arch: str):
    def build(reduced: bool = False) -> ArchConfig:
        return get_reduced(arch) if reduced else get(arch)
    build.__doc__ = (f"ArchConfig for {arch} "
                     "(reduced=True -> CPU-scale smoke variant).")
    build.__name__ = f"arch_{_MOD[arch]}"
    return build


for _a in ARCH_IDS + PAPER_ARCHS:
    if not registry.is_registered("arch", _a):
        registry.register("arch", _a)(_arch_factory(_a))
del _a
