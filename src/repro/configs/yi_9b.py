"""yi-9b [dense] — llama-arch GQA [arXiv:2403.04652]."""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="yi-9b", family="dense",
        n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab_size=64000, head_dim=128,
        window=8192, source="arXiv:2403.04652",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="yi-9b-reduced", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32,
        window=8192, source="arXiv:2403.04652",
    )
