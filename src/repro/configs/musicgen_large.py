"""musicgen-large [audio] — decoder-only over EnCodec tokens [arXiv:2306.05284].

Backbone-only scope (assignment carve-out): the EnCodec feature extractor /
text conditioner is a stub frontend delivering 64 conditioning frame
embeddings consumed as a projected prefix (MusicGen's cross-attention
conditioning is modelled as prefix conditioning — noted in DESIGN.md).  The
decoder operates over the 2048-entry codebook vocabulary; the 4-codebook
delay pattern is collapsed to a single stream per the backbone-only scope."""
from repro.config import ArchConfig, FrontendConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large", family="audio",
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab_size=2048, head_dim=64,
        window=8192,
        frontend=FrontendConfig(kind="audio", n_tokens=64, embed_dim=768),
        source="arXiv:2306.05284",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="musicgen-large-reduced", family="audio",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=512, head_dim=64,
        window=8192,
        frontend=FrontendConfig(kind="audio", n_tokens=8, embed_dim=64),
        source="arXiv:2306.05284",
    )
