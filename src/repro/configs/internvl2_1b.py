"""internvl2-1b [vlm] — InternViT + InternLM2 [arXiv:2404.16821].

Backbone-only scope (assignment carve-out): the InternViT vision encoder is a
stub frontend delivering 256 precomputed patch embeddings (1024-dim, the
InternViT-300M width) that the implemented Qwen2-style decoder consumes as a
projected prefix."""
from repro.config import ArchConfig, FrontendConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b", family="vlm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab_size=151655, head_dim=64,
        window=8192,
        frontend=FrontendConfig(kind="vision", n_tokens=256, embed_dim=1024),
        source="arXiv:2404.16821",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="internvl2-1b-reduced", family="vlm",
        n_layers=2, d_model=224, n_heads=7, n_kv_heads=1,
        d_ff=448, vocab_size=512, head_dim=32,
        window=8192,
        frontend=FrontendConfig(kind="vision", n_tokens=16, embed_dim=64),
        source="arXiv:2404.16821",
    )
