"""qwen3-32b [dense] — qk_norm, GQA [hf:Qwen/Qwen3-8B]."""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8,
        d_ff=25600, vocab_size=151936, head_dim=128,
        qk_norm=True, window=8192, source="hf:Qwen/Qwen3-8B",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-32b-reduced", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32,
        qk_norm=True, window=8192, source="hf:Qwen/Qwen3-8B",
    )
