"""zamba2-2.7b [hybrid] — Mamba2 + shared attn blocks [arXiv:2411.15242].

54 Mamba2 layers with a single *shared* attention+MLP block (one parameter
set, reused) applied every 6 layers — 9 application sites, each with its own
KV cache.  ssm_state=64.  long_500k runs natively (state carries long-range;
the shared attention uses its sliding window)."""
from repro.config import ArchConfig, HybridConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab_size=32000, head_dim=80,
        window=8192,
        ssm=SSMConfig(d_state=64, expand=2, head_dim=64, chunk=128, d_conv=4),
        hybrid=HybridConfig(attn_every=6, shared_attn=True),
        source="arXiv:2411.15242",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b-reduced", family="hybrid",
        n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        d_ff=512, vocab_size=512, head_dim=64,
        window=8192,
        ssm=SSMConfig(d_state=32, expand=2, head_dim=32, chunk=32, d_conv=4),
        hybrid=HybridConfig(attn_every=1, shared_attn=True),
        source="arXiv:2411.15242",
    )
