"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434].

d_ff=1536 is the routed-expert width; the first layer is dense with the
model-card dense width 12288.  n_kv_heads=128 reflects MLA (every head reads
the shared rank-512 latent; there is no classic KV grouping).
"""
from repro.config import ArchConfig, MLAConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b", family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=12288,                 # dense width of the first_k_dense layer
        vocab_size=102400,
        window=8192,
        moe=MoEConfig(n_experts=160, top_k=6, n_shared_experts=2,
                      expert_d_ff=1536, first_k_dense=1),
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        source="arXiv:2405.04434",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-236b-reduced", family="moe",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
        d_ff=512, vocab_size=512,
        window=8192,
        moe=MoEConfig(n_experts=4, top_k=2, n_shared_experts=1,
                      expert_d_ff=128, first_k_dense=1),
        mla=MLAConfig(kv_lora_rank=64, q_lora_rank=96,
                      qk_nope_head_dim=32, qk_rope_head_dim=16,
                      v_head_dim=32),
        source="arXiv:2405.04434",
    )
