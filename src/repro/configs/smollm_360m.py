"""smollm-360m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M]."""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab_size=49152, head_dim=64,
        window=8192,  # sliding-window variant engaged only at long_500k
        source="hf:HuggingFaceTB/SmolLM-135M",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="smollm-360m-reduced", family="dense",
        n_layers=2, d_model=192, n_heads=6, n_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32,
        window=8192, source="hf:HuggingFaceTB/SmolLM-135M",
    )
