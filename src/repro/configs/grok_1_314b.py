"""grok-1-314b [moe] — 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.config import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b", family="moe",
        n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8,
        d_ff=32768, vocab_size=131072, head_dim=128,
        window=8192,
        moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=32768),
        source="hf:xai-org/grok-1",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="grok-1-314b-reduced", family="moe",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32,
        window=8192,
        moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=512),
        source="hf:xai-org/grok-1",
    )
