"""flux_dit [dit] — the paper's own model family: a FLUX.1-style rectified-flow
DiT (Black Forest Labs) scaled to ~100M for the end-to-end RL examples.

Bidirectional attention over latent tokens, adaLN-zero time/condition
modulation; this is the backbone the paper fine-tunes with GRPO/NFT/AWM
(paper §4 uses FLUX.1-dev at 12B — same family, full scale is exercised via
the dry-run like every other config).  vocab_size is unused (continuous
latents); it sizes the stub condition vocabulary."""
from repro.config import ArchConfig


def config() -> ArchConfig:
    # ~12B-class full config (FLUX.1-dev-like geometry)
    return ArchConfig(
        name="flux_dit", family="dit",
        n_layers=38, d_model=3072, n_heads=24, n_kv_heads=24,
        d_ff=12288, vocab_size=32768, head_dim=128,
        qk_norm=True, window=0,
        source="bfl.ai FLUX.1-dev (paper §4)",
    )


def reduced() -> ArchConfig:
    # ~100M driver model used by examples/train_grpo_e2e.py
    return ArchConfig(
        name="flux_dit-reduced", family="dit",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
        d_ff=1024, vocab_size=512, head_dim=32,
        qk_norm=True, window=0,
        source="bfl.ai FLUX.1-dev (paper §4)",
    )
