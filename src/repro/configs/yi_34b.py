"""yi-34b [dense] — llama-arch GQA [arXiv:2403.04652]."""
from repro.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="yi-34b", family="dense",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab_size=64000, head_dim=128,
        window=8192, source="arXiv:2403.04652",
    )


def reduced() -> ArchConfig:
    return ArchConfig(
        name="yi-34b-reduced", family="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
        d_ff=512, vocab_size=512, head_dim=32,
        window=8192, source="arXiv:2403.04652",
    )
