"""Reusable RL training loop with a small callback protocol.

One loop serves every entry point (``launch/train.py``, the examples, sweep
workers): iterate the prompt dataset, fetch condition embeddings from the
:class:`ConditionProvider`, run ``trainer.step``, and fan the metric row out
to callbacks.  Checkpointing saves the trainer's **full** ``RLState``
(params *and* AdamW moments), so a resumed run continues bit-identically.

Pipelining (``LoopConfig.pipeline``): ``trainer.step`` returns *device*
scalars, and jax dispatch is asynchronous — the only thing that forces the
host to wait for step N is fetching its metrics.  With ``pipeline=K`` the
loop keeps up to K dispatched-not-yet-drained steps in a queue and fetches
metrics one-or-more steps late, so the host-side work of iteration N+1
(prompt batching, condition lookup, metric-row IO) overlaps step N's device
execution.  Buffer donation single-buffers the RLState, so the in-flight
depth is bounded by design; K only bounds the *metric* lag.  One backend
caveat: the CPU PJRT client runs a *donated* execution synchronously when
its input buffer came off the device, so on XLA:CPU ``trainer.step`` only
returns once the update finished and nothing is ever in flight — set
``dist.donate_state=false`` there to get real run-ahead (double-buffers
the state; on GPU/TPU donation dispatches asynchronously and should stay
on).  The contract:

* ``pipeline=1`` is bit-identical to the historical sequential loop —
  same dispatch order, same keys, same rows, same callback timing.
* ``pipeline>1`` changes *when* metrics are observed, never *what* is
  computed: params after N steps are bitwise equal for every K, the rows
  are the same set, and callbacks still fire in step order — just lagged.

Callbacks are lag-aware: they fire on *drained* steps.  A callback that
must see ``trainer.state`` exactly as of its step (``PeriodicCheckpoint``)
returns True from :meth:`Callback.wants_sync`; the loop then drains fully
after dispatching that step, before anything newer is dispatched — with
donation there is exactly one live state, so the barrier is what keeps
crash/resume bit-identical under any K.  :class:`EarlyStop` observes
metrics up to K-1 steps late, so a stop request lands after at most K-1
extra dispatched steps (which are drained and logged — they did run).

Per-row timing under pipelining: ``dt`` is the dispatch→drain latency of
that step (for K=1 exactly the old per-step wall time), while
``steps_per_s`` is the end-to-end drained-step rate excluding the first
(compile-laden) step — the number that shows the overlap win.  The window
is anchored at the *dispatch* of the second step, not its drain: drain
times bunch when the device runs ahead during a blocking fetch (a short
pipelined run drains its whole tail microseconds apart), and a
drain-to-drain span would then divide by ~zero.  Every counted step's
device work happens after its dispatch, and the jit trace/compile block
lives in the first step's dispatch, so the dispatch anchor measures real
work.  Reporting ``dt`` and ``steps_per_s`` separately avoids the PR-3
"inf req/s" artifact class: a lagged drain makes per-step deltas
meaningless as throughput.

Built-in callbacks: :class:`MetricLogger` (console), :class:`JSONLogSink`
(metric-log file), :class:`PeriodicCheckpoint` (full-state saves),
:class:`EarlyStop` (patience on any metric).  Custom callbacks subclass
:class:`Callback` and may call ``loop.request_stop()``.
"""
from __future__ import annotations

import json
import os
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax

from repro import checkpoint


def _no_sync(loop: "TrainLoop", step: int) -> bool:
    """Default for duck-typed callbacks that don't define ``wants_sync``."""
    return False


class Callback:
    """No-op base; override any subset of the hooks."""

    def on_train_start(self, loop: "TrainLoop") -> None:
        pass

    def on_step(self, loop: "TrainLoop", step: int,
                metrics: Dict[str, Any]) -> None:
        pass

    def wants_sync(self, loop: "TrainLoop", step: int) -> bool:
        """Return True if ``on_step(step)`` must observe ``trainer.state``
        exactly as of ``step``.  The loop then drains every in-flight step
        (including this one) before dispatching anything newer — donation
        keeps a single live state, so this barrier is the only way a
        callback can see the post-``step`` state under ``pipeline>1``."""
        return False

    def on_train_end(self, loop: "TrainLoop",
                     history: List[Dict[str, Any]]) -> None:
        pass


class MetricLogger(Callback):
    """Console progress every ``every`` steps (and on the final step).

    Prints both per-row numbers the loop reports under pipelining: ``dt``
    (that step's dispatch→drain latency) and ``steps/s`` (end-to-end
    drained-step throughput, compile step excluded)."""

    def __init__(self, every: int = 10):
        self.every = every

    def on_step(self, loop, step, metrics):
        if self.every and (step % self.every == 0
                           or step == loop.steps - 1):
            sps = metrics.get("steps_per_s", 0.0)
            print(f"  step {step:4d}  reward={metrics['reward']:+.4f}  "
                  f"loss={metrics['loss']:+.4f}  dt={metrics['dt']:.2f}s  "
                  f"{sps:.2f} steps/s",
                  flush=True)


class JSONLogSink(Callback):
    """Maintain the full metric history at ``path`` as a JSON array,
    flushed incrementally so a crashed/preempted run keeps every step it
    logged (``PeriodicCheckpoint`` already saved the state; losing the
    metric history to a crash made the two sinks inconsistent).

    Each flush writes the whole array to a temp file and atomically renames
    it over ``path`` — a kill mid-write can never leave a truncated log.
    ``flush_every`` throttles the rewrite for long runs (the final state is
    always written at train end).

    Lag-aware for free: rows are appended to ``loop.history`` at *drain*
    time, in step order, so under ``pipeline>1`` the log never contains a
    step whose device work had not finished — a crash mid-pipeline loses
    only not-yet-drained steps, which resume recomputes bit-identically.

    Resume-aware: rows from a previous (interrupted) run that precede this
    run's ``start_step`` are preserved, so the log always covers step 0..N
    even across restarts; a resume with nothing left to do keeps the
    existing log untouched."""

    def __init__(self, path: str, flush_every: int = 1):
        self.path = path
        self.flush_every = max(1, flush_every)
        self._prior: List[Dict[str, Any]] = []

    def on_train_start(self, loop):
        self._prior = []
        if loop.start_step and os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    rows = json.load(f)
                self._prior = [r for r in rows if r.get("step", -1)
                               < loop.start_step]
            except (ValueError, OSError):
                pass                     # unreadable prior log: start fresh

    def _flush(self, history) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._prior + history, f)
        os.replace(tmp, self.path)

    def on_step(self, loop, step, metrics):
        if len(loop.history) % self.flush_every == 0:
            self._flush(loop.history)

    def on_train_end(self, loop, history):
        if not history:
            return                       # nothing ran: leave the log alone
        self._flush(history)


class PeriodicCheckpoint(Callback):
    """Save the trainer's full RLState every ``every`` steps.

    Declares ``wants_sync`` on its save steps: with donation the trainer
    holds ONE live state (the newest dispatched step's), so the loop must
    drain the pipeline before the save for the checkpoint to be exactly
    the post-``step`` state — which is what keeps resume bit-identical
    under any ``pipeline`` depth."""

    def __init__(self, ckpt_dir: str, every: int = 50):
        self.ckpt_dir = ckpt_dir
        self.every = every

    def wants_sync(self, loop, step):
        return bool(self.every) and (step + 1) % self.every == 0

    def on_step(self, loop, step, metrics):
        if self.every and (step + 1) % self.every == 0:
            checkpoint.save_checkpoint(self.ckpt_dir, step + 1,
                                       loop.trainer.state)


class EarlyStop(Callback):
    """Stop when ``metric`` hasn't improved by ``min_delta`` for
    ``patience`` consecutive steps (higher is better).

    Under ``pipeline=K`` the metrics arrive up to K-1 steps late, so the
    stop request lands after at most K-1 extra steps were dispatched;
    those are drained and logged (their device work already ran) and the
    loop stops before dispatching anything further."""

    def __init__(self, metric: str = "reward", patience: int = 20,
                 min_delta: float = 0.0):
        self.metric = metric
        self.patience = patience
        self.min_delta = min_delta
        self.best: Optional[float] = None
        self.stale = 0

    def on_step(self, loop, step, metrics):
        val = float(metrics[self.metric])
        if self.best is None or val > self.best + self.min_delta:
            self.best, self.stale = val, 0
            return
        self.stale += 1
        if self.stale >= self.patience:
            print(f"[early-stop] {self.metric} stalled at {self.best:+.4f} "
                  f"for {self.patience} steps", flush=True)
            loop.request_stop()


class TrainLoop:
    """Drive ``trainer.step`` over a prompt dataset.

    ``start_step > 0`` resumes: the data stream is fast-forwarded past the
    batches already consumed (``dataset.infinite(skip)`` — O(1) for
    :class:`repro.data.prompts.PromptDataset`; datasets without the skip
    parameter are replay-skipped) and iteration keys are re-derived from
    the step index (``trainer.step`` folds the key by ``it``), so a
    resumed run replays the exact schedule of an uninterrupted one.

    ``pipeline`` is the max number of dispatched-not-yet-drained steps
    (see the module docstring for the exactness contract).  Between a
    dispatch and its drain the loop also arms the overlap hooks when the
    collaborators provide them: ``provider.prefetch(next_prompts)`` warms
    the next condition batch on a background thread, and
    ``trainer.prefetch_reward_params()`` starts the H2D copy of
    host-offloaded reward towers (``perf.offload_rewards``) — both run
    while the in-flight step's device work proceeds.
    """

    def __init__(self, trainer, provider, dataset, *, steps: int,
                 key: jax.Array, start_step: int = 0,
                 callbacks: Sequence[Callback] = (), pipeline: int = 1):
        if pipeline < 1:
            raise ValueError(f"pipeline depth must be >= 1, got {pipeline}")
        self.trainer = trainer
        self.provider = provider
        self.dataset = dataset
        self.steps = steps
        self.key = key
        self.start_step = start_step
        self.callbacks = list(callbacks)
        self.pipeline = pipeline
        self.history: List[Dict[str, Any]] = []
        self._stop = False
        self._t_window0: Optional[float] = None
        self._n_drained = 0

    def request_stop(self) -> None:
        self._stop = True

    # ------------------------------------------------------------- plumbing
    def _stream(self):
        """Prompt-batch iterator positioned past ``start_step`` batches."""
        try:
            return self.dataset.infinite(self.start_step)
        except TypeError:
            # dataset without the skip fast path: replay-skip (O(n))
            stream = self.dataset.infinite()
            for _ in range(self.start_step):
                next(stream)
            return stream

    def _drain_one(self, pending: Deque[Tuple[int, Any, float]]) -> None:
        """Fetch the oldest in-flight step's metrics and fan out the row.
        ONE host transfer for the whole metric dict — the trainer returns
        device scalars (reward_mean included, computed inside the
        rewards/fused jit); fetching per-metric with float() cost ~8
        separate syncs per step.  Converting at the transfer site keeps
        the loop body sync-free (jaxlint R002/R007)."""
        it, metrics, t_dispatch = pending.popleft()
        m = jax.tree.map(float, jax.device_get(metrics))
        now = time.time()
        self._n_drained += 1
        # end-to-end drained-step rate over a window anchored at the SECOND
        # step's dispatch: the first step carries the compile, and anchoring
        # at a drain time is unsafe — tail drains bunch microseconds apart
        # once the device has run ahead, collapsing the span (the PR-3
        # "inf req/s" artifact class)
        span = (now - self._t_window0
                if self._t_window0 is not None else 0.0)
        sps = ((self._n_drained - 1) / span
               if self._n_drained > 1 and span > 0 else 0.0)
        row: Dict[str, Any] = {
            "step": it,
            "reward": m["reward_mean"],
            "loss": m["loss"],
            "grad_norm": m["grad_norm"],
            "encode_resident": self.provider.encoder_resident,
            "dt": round(now - t_dispatch, 3),
            "steps_per_s": round(sps, 3),
        }
        for k, v in m.items():
            if k.startswith("reward/"):
                row[k] = v
        self.history.append(row)
        for cb in self.callbacks:
            cb.on_step(self, it, row)

    # ------------------------------------------------------------------ run
    def run(self) -> List[Dict[str, Any]]:
        for cb in self.callbacks:
            cb.on_train_start(self)
        self._t_window0 = None
        self._n_drained = 0
        stream = self._stream()
        pending: Deque[Tuple[int, Any, float]] = deque()
        next_prompts: Optional[List[str]] = None
        can_prefetch = hasattr(self.provider, "prefetch")
        can_prefetch_rewards = hasattr(self.trainer,
                                       "prefetch_reward_params")
        for it in range(self.start_step, self.steps):
            if self._stop:
                break
            prompts = next_prompts if next_prompts is not None \
                else next(stream)
            next_prompts = None
            cond = self.provider.get(prompts)["cond"]
            t_dispatch = time.time()
            pending.append((it, self.trainer.step(cond, self.key, it=it),
                            t_dispatch))
            if it == self.start_step + 1:  # second dispatch: post-compile
                self._t_window0 = t_dispatch
            # overlap host work with the in-flight device step(s): pull the
            # next prompt batch, warm its conditions, start the reward-tower
            # H2D copy — all before blocking on any drain
            if it + 1 < self.steps:
                next_prompts = next(stream)
                if can_prefetch:
                    self.provider.prefetch(next_prompts)
            if can_prefetch_rewards:
                self.trainer.prefetch_reward_params()
            # a sync-hungry callback (checkpoint) forces a full drain so it
            # observes trainer.state exactly as of this step; otherwise keep
            # at most `pipeline` steps in flight (duck-typed: user callbacks
            # need not subclass Callback, so wants_sync is optional)
            barrier = any(
                getattr(cb, "wants_sync", _no_sync)(self, it)
                for cb in self.callbacks)
            limit = 0 if barrier else self.pipeline - 1
            while len(pending) > limit:
                self._drain_one(pending)
        while pending:                    # drain the tail (and on stop: the
            self._drain_one(pending)      # already-dispatched steps DID run)
        for cb in self.callbacks:
            cb.on_train_end(self, self.history)
        return self.history
