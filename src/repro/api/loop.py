"""Reusable RL training loop with a small callback protocol.

One loop serves every entry point (``launch/train.py``, the examples, sweep
workers): iterate the prompt dataset, fetch condition embeddings from the
:class:`ConditionProvider`, run ``trainer.step``, and fan the metric row out
to callbacks.  Checkpointing saves the trainer's **full** ``RLState``
(params *and* AdamW moments), so a resumed run continues bit-identically.

Built-in callbacks: :class:`MetricLogger` (console), :class:`JSONLogSink`
(metric-log file), :class:`PeriodicCheckpoint` (full-state saves),
:class:`EarlyStop` (patience on any metric).  Custom callbacks subclass
:class:`Callback` and may call ``loop.request_stop()``.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, List, Optional, Sequence

import jax

from repro import checkpoint


class Callback:
    """No-op base; override any subset of the hooks."""

    def on_train_start(self, loop: "TrainLoop") -> None:
        pass

    def on_step(self, loop: "TrainLoop", step: int,
                metrics: Dict[str, Any]) -> None:
        pass

    def on_train_end(self, loop: "TrainLoop",
                     history: List[Dict[str, Any]]) -> None:
        pass


class MetricLogger(Callback):
    """Console progress every ``every`` steps (and on the final step)."""

    def __init__(self, every: int = 10):
        self.every = every

    def on_step(self, loop, step, metrics):
        if self.every and (step % self.every == 0
                           or step == loop.steps - 1):
            print(f"  step {step:4d}  reward={metrics['reward']:+.4f}  "
                  f"loss={metrics['loss']:+.4f}  dt={metrics['dt']:.2f}s",
                  flush=True)


class JSONLogSink(Callback):
    """Maintain the full metric history at ``path`` as a JSON array,
    flushed incrementally so a crashed/preempted run keeps every step it
    logged (``PeriodicCheckpoint`` already saved the state; losing the
    metric history to a crash made the two sinks inconsistent).

    Each flush writes the whole array to a temp file and atomically renames
    it over ``path`` — a kill mid-write can never leave a truncated log.
    ``flush_every`` throttles the rewrite for long runs (the final state is
    always written at train end).

    Resume-aware: rows from a previous (interrupted) run that precede this
    run's ``start_step`` are preserved, so the log always covers step 0..N
    even across restarts; a resume with nothing left to do keeps the
    existing log untouched."""

    def __init__(self, path: str, flush_every: int = 1):
        self.path = path
        self.flush_every = max(1, flush_every)
        self._prior: List[Dict[str, Any]] = []

    def on_train_start(self, loop):
        self._prior = []
        if loop.start_step and os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    rows = json.load(f)
                self._prior = [r for r in rows if r.get("step", -1)
                               < loop.start_step]
            except (ValueError, OSError):
                pass                     # unreadable prior log: start fresh

    def _flush(self, history) -> None:
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._prior + history, f)
        os.replace(tmp, self.path)

    def on_step(self, loop, step, metrics):
        if len(loop.history) % self.flush_every == 0:
            self._flush(loop.history)

    def on_train_end(self, loop, history):
        if not history:
            return                       # nothing ran: leave the log alone
        self._flush(history)


class PeriodicCheckpoint(Callback):
    """Save the trainer's full RLState every ``every`` steps."""

    def __init__(self, ckpt_dir: str, every: int = 50):
        self.ckpt_dir = ckpt_dir
        self.every = every

    def on_step(self, loop, step, metrics):
        if self.every and (step + 1) % self.every == 0:
            checkpoint.save_checkpoint(self.ckpt_dir, step + 1,
                                       loop.trainer.state)


class EarlyStop(Callback):
    """Stop when ``metric`` hasn't improved by ``min_delta`` for
    ``patience`` consecutive steps (higher is better)."""

    def __init__(self, metric: str = "reward", patience: int = 20,
                 min_delta: float = 0.0):
        self.metric = metric
        self.patience = patience
        self.min_delta = min_delta
        self.best: Optional[float] = None
        self.stale = 0

    def on_step(self, loop, step, metrics):
        val = float(metrics[self.metric])
        if self.best is None or val > self.best + self.min_delta:
            self.best, self.stale = val, 0
            return
        self.stale += 1
        if self.stale >= self.patience:
            print(f"[early-stop] {self.metric} stalled at {self.best:+.4f} "
                  f"for {self.patience} steps", flush=True)
            loop.request_stop()


class TrainLoop:
    """Drive ``trainer.step`` over a prompt dataset.

    ``start_step > 0`` resumes: the data stream is advanced past the batches
    already consumed and iteration keys are re-derived from the step index
    (``trainer.step`` folds the key by ``it``), so a resumed run replays the
    exact schedule of an uninterrupted one.
    """

    def __init__(self, trainer, provider, dataset, *, steps: int,
                 key: jax.Array, start_step: int = 0,
                 callbacks: Sequence[Callback] = ()):
        self.trainer = trainer
        self.provider = provider
        self.dataset = dataset
        self.steps = steps
        self.key = key
        self.start_step = start_step
        self.callbacks = list(callbacks)
        self.history: List[Dict[str, Any]] = []
        self._stop = False

    def request_stop(self) -> None:
        self._stop = True

    def run(self) -> List[Dict[str, Any]]:
        for cb in self.callbacks:
            cb.on_train_start(self)
        stream = self.dataset.infinite()
        for _ in range(self.start_step):       # replay-skip consumed batches
            next(stream)
        for it in range(self.start_step, self.steps):
            t_it = time.time()
            prompts = next(stream)
            cond = self.provider.get(prompts)["cond"]
            # ONE host transfer for the whole metric dict — the trainer
            # returns device scalars (reward_mean included, computed inside
            # the rewards/fused jit); fetching per-metric with float() cost
            # ~8 separate syncs per step.  Converting at the transfer site
            # keeps the loop body sync-free (jaxlint R002).
            m = jax.tree.map(
                float, jax.device_get(
                    self.trainer.step(cond, self.key, it=it)))
            row: Dict[str, Any] = {
                "step": it,
                "reward": m["reward_mean"],
                "loss": m["loss"],
                "grad_norm": m["grad_norm"],
                "encode_resident": self.provider.encoder_resident,
                "dt": round(time.time() - t_it, 3),
            }
            for k, v in m.items():
                if k.startswith("reward/"):
                    row[k] = v
            self.history.append(row)
            for cb in self.callbacks:
                cb.on_step(self, it, row)
            if self._stop:
                break
        for cb in self.callbacks:
            cb.on_train_end(self, self.history)
        return self.history
