"""``Experiment`` — the config-first front door for train / serve / sweep.

One declarative :class:`RunConfig` reaches every (model × algorithm × reward
× scheduler) combination through the registry (the paper's §2.1 O(M+N)
claim): arch (including ``reduced`` CPU variants and declarative
``arch_overrides``), trainer, SDE scheduler, rewards, optimizer, dataset and
the preprocessing :class:`ConditionProvider` are all resolved by name — no
entry point hand-rolls argparse → config → loop → checkpoint anymore.

    from repro.api import Experiment

    exp = Experiment.from_file("run.json")          # or .from_config(cfg)
    result = exp.train()                            # shared TrainLoop

    exp = Experiment.from_cli(["--reduced", "--steps", "2",
                               "--set", "flow.eta=0.5"])

CLI flags are one ``--config`` JSON plus dotted ``--set path=value``
overrides; the few convenience flags (``--arch/--trainer/--sde``) derive
their choices from ``registry.names(...)`` so they can never drift from
what is actually registered.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
from typing import Any, Dict, List, Optional, Sequence

import jax

from repro import checkpoint, registry
from repro.api import loop as loop_lib
from repro.api.overrides import apply_overrides, replace_fields
from repro.api.serving import FlowSampler
from repro.config import (ArchConfig, ConfigError, FlowRLConfig, LoopConfig,
                          OptimConfig, RewardSpec, RunConfig, load_json,
                          to_dict)
from repro.core.preprocess import (ConditionProvider, PreprocessCache,
                                   preprocess_dataset)


def default_cli_config() -> RunConfig:
    """CPU-friendly defaults matching the historical launcher: small latent
    geometry, text_render reward, 100-step schedule."""
    return RunConfig(
        arch="flux_dit",
        flow=FlowRLConfig(
            num_steps=8, group_size=4, latent_tokens=16, latent_dim=8,
            rewards=(RewardSpec("text_render", 1.0),)),
        optim=OptimConfig(lr=3e-4, total_steps=100, warmup_steps=5),
        loop=LoopConfig(steps=100))


class Experiment:
    """A fully-resolved run: config in, trained state / served latents out."""

    def __init__(self, cfg: RunConfig):
        self.cfg = cfg
        self._arch: Optional[ArchConfig] = None
        self._trainer = None
        self._dataset = None

    # ------------------------------------------------------------ construct
    @classmethod
    def from_config(cls, cfg: RunConfig, overrides: Sequence[str] = ()
                    ) -> "Experiment":
        if overrides:
            cfg = apply_overrides(cfg, overrides)
        return cls(cfg)

    @classmethod
    def from_file(cls, path: str, overrides: Sequence[str] = ()
                  ) -> "Experiment":
        return cls.from_config(load_json(RunConfig, path), overrides)

    @classmethod
    def cli_parser(cls, description: str = "Flow-Factory experiment"
                   ) -> argparse.ArgumentParser:
        """Shared parser: one config file + dotted overrides; convenience
        flag choices are *derived* from the registry, never hard-coded."""
        ap = argparse.ArgumentParser(description=description)
        ap.add_argument("--config", default="",
                        help="RunConfig JSON (default: built-in CPU profile)")
        ap.add_argument("--arch", default=None,
                        choices=registry.names("arch"))
        ap.add_argument("--reduced", action="store_true",
                        help="use the ≤2-layer reduced config (CPU-runnable)")
        ap.add_argument("--trainer", default=None,
                        choices=registry.names("trainer"))
        ap.add_argument("--sde", default=None,
                        choices=registry.names("scheduler"))
        ap.add_argument("--steps", type=int, default=None)
        ap.add_argument("--set", dest="overrides", action="append",
                        default=[], metavar="DOTTED.PATH=VALUE",
                        help="typed config override, e.g. --set flow.eta=0.5 "
                             "or --set dist.model_parallel=2")
        return ap

    @classmethod
    def from_args(cls, ns: argparse.Namespace,
                  base: Optional[RunConfig] = None) -> "Experiment":
        cfg = (load_json(RunConfig, ns.config) if ns.config
               else (base or default_cli_config()))
        pre: Dict[str, Any] = {}
        if ns.arch is not None:
            pre["arch"] = ns.arch
        if ns.reduced:
            pre["reduced"] = True
        if ns.trainer is not None:
            pre["flow.trainer_type"] = ns.trainer
        if ns.sde is not None:
            pre["flow.sde_type"] = ns.sde
        if ns.steps is not None:
            pre["loop.steps"] = ns.steps
            pre["optim.total_steps"] = ns.steps
            pre["optim.warmup_steps"] = max(2, ns.steps // 20)
        cfg = apply_overrides(cfg, pre)
        return cls.from_config(cfg, ns.overrides)

    @classmethod
    def from_cli(cls, argv: Optional[Sequence[str]] = None,
                 base: Optional[RunConfig] = None) -> "Experiment":
        return cls.from_args(cls.cli_parser().parse_args(argv), base)

    # -------------------------------------------------------------- resolve
    @property
    def arch(self) -> ArchConfig:
        if self._arch is None:
            arch = registry.build("arch", self.cfg.arch,
                                  reduced=self.cfg.reduced)
            self._arch = replace_fields(arch, self.cfg.arch_overrides)
        return self._arch

    @property
    def cond_dim(self) -> int:
        return int(self.cfg.data.encoder.get("cond_dim", 512))

    @property
    def cond_len(self) -> int:
        return int(self.cfg.data.encoder.get("cond_len", 16))

    @property
    def flow(self) -> FlowRLConfig:
        """FlowRLConfig with reward args auto-completed: any reward
        parameter named latent_dim / latent_tokens / cond_dim that the spec
        leaves unset is filled from the run's latent/condition geometry, so
        configs state it once instead of once per reward."""
        f = self.cfg.flow
        auto = {"latent_dim": f.latent_dim, "latent_tokens": f.latent_tokens,
                "cond_dim": self.cond_dim}
        filled = []
        for spec in f.rewards:
            accepted = registry.describe("reward", spec.reward_type)["params"]
            args = dict(spec.args)
            args.update({k: v for k, v in auto.items()
                         if k in accepted and k not in args})
            filled.append(dataclasses.replace(spec, args=args))
        return dataclasses.replace(f, rewards=tuple(filled))

    def build_dataset(self):
        if self._dataset is None:
            d = self.cfg.data
            self._dataset = registry.build_from_config(
                "dataset",
                {"type": d.dataset,
                 "args": {"n_prompts": d.n_prompts,
                          "batch_prompts": d.batch_prompts,
                          "seed": self.cfg.seed, **d.args}})
        return self._dataset

    def build_provider(self, prompts: Optional[Sequence[str]] = None,
                       live: bool = False) -> ConditionProvider:
        """Phase 1 (paper §2.2): with preprocessing on, encode+cache
        ``prompts`` once and return a cache-backed provider (encoders
        offloaded); otherwise a live-encoding provider."""
        f, d = self.cfg.flow, self.cfg.data
        if live or not f.preprocessing:
            return ConditionProvider(preprocessing=False,
                                     encoder_kw=dict(d.encoder))
        # sub-directory per encoder config: cache entries are keyed by
        # prompt hash only, so a changed encoder geometry must not silently
        # reuse embeddings cached under the old one
        enc_tag = hashlib.sha1(
            json.dumps(d.encoder, sort_keys=True).encode()).hexdigest()[:10]
        cache = PreprocessCache(os.path.join(f.cache_dir, f"enc_{enc_tag}"))
        if prompts:
            preprocess_dataset(prompts, cache, **d.encoder)
        return ConditionProvider(preprocessing=True, cache=cache)

    def build_trainer(self, key: Optional[jax.Array] = None):
        if self._trainer is None:
            key = (jax.random.PRNGKey(self.cfg.seed) if key is None else key)
            self._trainer = registry.build_from_config(
                "trainer", self.cfg.flow.trainer_type,
                self.arch, self.flow, self.cfg.optim,
                key=key, cond_dim=self.cond_dim, dist=self.cfg.dist,
                perf=self.cfg.perf)
        return self._trainer

    def build_sampler(self, key: Optional[jax.Array] = None,
                      max_batch: int = 8, params=None,
                      buckets: Optional[Sequence[int]] = None,
                      step_tiers: Optional[Sequence[int]] = None,
                      deadline_s: float = 0.005, admission=None,
                      max_inflight: int = 4,
                      provider=None) -> FlowSampler:
        """``params`` priority: explicit argument > this Experiment's
        trained state (if ``train()`` ran) > fresh init.  The sampler's
        engine shards inference over ``cfg.dist`` (``data_parallel>1``
        shards requests over the mesh's "data" axis with per-request
        output bit-identical to single-device; ``model_parallel>1`` keeps
        params model-sharded per the PartitionPlan, f32-rounding-equal).
        ``step_tiers`` is the admitted num_steps quality
        ladder; ``admission`` an :class:`repro.serving.AdmissionConfig`
        (priority classes, tenant weights, bounded queues)."""
        from repro import distributed
        key = jax.random.PRNGKey(self.cfg.seed) if key is None else key
        if params is None and self._trainer is not None:
            params = self._trainer.state.params
        return FlowSampler(self.arch, self.flow, key=key,
                           max_batch=max_batch, cond_dim=self.cond_dim,
                           params=params, buckets=buckets,
                           step_tiers=step_tiers, deadline_s=deadline_s,
                           admission=admission, max_inflight=max_inflight,
                           mesh=distributed.train_mesh(self.cfg.dist),
                           provider=provider, cond_len=self.cond_len)

    def describe(self) -> Dict[str, Any]:
        """Resolved-component summary (uses ``registry.describe``).  The
        ``dist`` entry shows the resolved 2-D mesh layout — how
        ``--set dist.data_parallel=2 --set dist.model_parallel=2`` landed
        against the local device count."""
        from repro import distributed
        f = self.cfg.flow
        dp, mp = distributed.resolve_axes(self.cfg.dist)
        return {
            "arch": {"name": self.arch.name, "family": self.arch.family,
                     "n_params": self.arch.n_params()},
            "trainer": registry.describe("trainer", f.trainer_type),
            "scheduler": registry.describe("scheduler", f.sde_type),
            "rewards": [s.reward_type for s in f.rewards],
            "optimizer": registry.describe("optimizer",
                                           self.cfg.optim.optimizer),
            "dataset": registry.describe("dataset", self.cfg.data.dataset),
            "dist": {"devices": jax.local_device_count(),
                     "data_parallel": dp, "model_parallel": mp,
                     "microbatch": self.cfg.dist.microbatch},
        }

    # ---------------------------------------------------------------- train
    def _ckpt_identity(self) -> Dict[str, Any]:
        """The config subset that must match for a checkpoint to be
        resumable.  Loop knobs and schedule length (``--steps`` extends a
        run, moving loop.steps + optim.total_steps/warmup_steps) may
        legitimately change between restarts, as may the device layout
        (``dist`` — a checkpoint written at one
        data_parallel×model_parallel/microbatch layout resumes at any
        other, and ``perf`` — remat/fusion/precision are
        performance policy, not what is being trained); everything else —
        arch, trainer, rewards, dynamics, data — is guarded against
        silently resuming someone else's state."""
        ident = to_dict(self.cfg)
        ident.pop("loop", None)
        ident.pop("dist", None)
        ident.pop("perf", None)
        for k in ("total_steps", "warmup_steps"):
            ident["optim"].pop(k, None)
        # normalize through JSON so tuples (rewards, betas) compare equal
        # to the lists they round-trip to on disk
        return json.loads(json.dumps(ident))

    def _identity_path(self, ckpt_dir: str) -> str:
        return os.path.join(ckpt_dir, "experiment.json")

    def _write_ckpt_identity(self, ckpt_dir: str) -> None:
        os.makedirs(ckpt_dir, exist_ok=True)
        with open(self._identity_path(ckpt_dir), "w") as f:
            json.dump(self._ckpt_identity(), f, indent=1)

    def _check_ckpt_identity(self, ckpt_dir: str) -> None:
        path = self._identity_path(ckpt_dir)
        if not os.path.exists(path):
            return                       # pre-identity checkpoint: tolerate
        with open(path) as f:
            saved = json.load(f)
        saved.pop("dist", None)                     # normalize like current
        saved.pop("perf", None)
        for k in ("total_steps", "warmup_steps"):
            saved.get("optim", {}).pop(k, None)
        current = self._ckpt_identity()
        if saved != current:
            diff = sorted(k for k in set(saved) | set(current)
                          if saved.get(k) != current.get(k))
            raise ConfigError(
                f"checkpoint dir {ckpt_dir!r} was written by a different "
                f"experiment (mismatched: {diff}); refusing to resume — "
                "point loop.ckpt_dir elsewhere or set loop.resume=false")

    def default_callbacks(self) -> List[loop_lib.Callback]:
        lc = self.cfg.loop
        cbs: List[loop_lib.Callback] = []
        if lc.log_every:
            cbs.append(loop_lib.MetricLogger(lc.log_every))
        # log sink BEFORE checkpoint: if the process dies between the two, a
        # flushed-but-not-checkpointed step is deduped on resume (prior-row
        # filter), while the reverse order would lose the row forever (the
        # checkpoint moves start_step past a step the log never recorded)
        if lc.log_file:
            cbs.append(loop_lib.JSONLogSink(lc.log_file,
                                            lc.log_flush_every))
        if lc.save_every:
            cbs.append(loop_lib.PeriodicCheckpoint(lc.ckpt_dir,
                                                   lc.save_every))
        if lc.early_stop_patience:
            cbs.append(loop_lib.EarlyStop(lc.early_stop_metric,
                                          lc.early_stop_patience,
                                          lc.early_stop_min_delta))
        return cbs

    def train(self, callbacks: Sequence[loop_lib.Callback] = (),
              resume: Optional[bool] = None) -> Dict[str, Any]:
        """Run the shared TrainLoop end-to-end.

        Returns ``{"history", "state", "start_step", "final_step"}``.  With
        ``resume`` (default: ``cfg.loop.resume``) the latest checkpoint in
        ``cfg.loop.ckpt_dir`` restores the **full** RLState — params and
        optimizer moments — before training continues.  ``callbacks``
        *extend* the config-driven defaults (disable those via the loop
        fields: ``log_every=0``, ``save_every=0``, ...)."""
        lc = self.cfg.loop
        key = jax.random.PRNGKey(self.cfg.seed)
        ds = self.build_dataset()
        provider = self.build_provider(ds.prompts)
        trainer = self.build_trainer(key)

        start_step = 0
        resume = lc.resume if resume is None else resume
        if resume and checkpoint.latest_step(lc.ckpt_dir) is not None:
            self._check_ckpt_identity(lc.ckpt_dir)
            try:
                step, state = checkpoint.restore_latest(lc.ckpt_dir,
                                                        trainer.state)
            except ValueError as e:
                raise ConfigError(
                    f"cannot resume from {lc.ckpt_dir!r}: {e} — set "
                    "loop.resume=false or point loop.ckpt_dir elsewhere"
                ) from None
            if step is not None:
                # checkpoints are canonical (unsharded) on disk; re-place
                # under this trainer's PartitionPlan so a dp=4 run resumes
                # cleanly at dp=2×mp=2 (or any other layout)
                trainer.state = trainer.place_state(state)
                start_step = step
                print(f"[resume] restored full RLState at step {step} "
                      f"from {lc.ckpt_dir}", flush=True)
        if lc.save_every:
            if not resume and checkpoint.latest_step(lc.ckpt_dir) is not None:
                # refusing beats silently re-labelling the dir: stale
                # higher-step checkpoints would win the next auto-resume
                raise ConfigError(
                    f"loop.ckpt_dir {lc.ckpt_dir!r} already contains "
                    "checkpoints; starting fresh (resume=false) would mix "
                    "runs — remove them or point loop.ckpt_dir elsewhere")
            self._write_ckpt_identity(lc.ckpt_dir)

        train_loop = loop_lib.TrainLoop(
            trainer, provider, ds, steps=lc.steps, key=key,
            start_step=start_step, pipeline=lc.pipeline,
            callbacks=self.default_callbacks() + list(callbacks))
        history = train_loop.run()
        final = history[-1]["step"] + 1 if history else start_step
        return {"history": history, "state": trainer.state,
                "start_step": start_step, "final_step": final}

    # ---------------------------------------------------------------- serve
    def build_engine(self, key: Optional[jax.Array] = None,
                     max_batch: int = 8, params=None,
                     buckets: Optional[Sequence[int]] = None,
                     step_tiers: Optional[Sequence[int]] = None,
                     deadline_s: float = 0.005, admission=None,
                     max_inflight: int = 4):
        """The serving engine directly (``repro.serving.ServingEngine``):
        submit/poll/drain request-queue API with priority classes,
        per-request SLO deadlines and admission control, warmup, and a
        JSON-serializable stats snapshot.  Prompts are encoded live
        through the engine's LRU cond cache — repeat prompts skip the
        ConditionProvider."""
        sampler = self.build_sampler(key, max_batch=max_batch, params=params,
                                     buckets=buckets, step_tiers=step_tiers,
                                     deadline_s=deadline_s,
                                     admission=admission,
                                     max_inflight=max_inflight,
                                     provider=self.build_provider(live=True))
        return sampler.engine

    def serve(self, prompts: Sequence[str], max_batch: int = 8,
              key: Optional[jax.Array] = None, params=None,
              buckets: Optional[Sequence[int]] = None,
              deadline_s: float = 0.005) -> jax.Array:
        """Batched sampling for a list of prompt requests -> latents
        (bucketed engine; ``cfg.dist.data_parallel`` shards inference)."""
        key = jax.random.PRNGKey(self.cfg.seed) if key is None else key
        # serving encodes live by default: requests are open-vocabulary, so
        # the preprocessing cache can't be assumed to cover them
        engine = self.build_engine(key, max_batch=max_batch, params=params,
                                   buckets=buckets, deadline_s=deadline_s)
        return engine.serve(list(prompts), key)
