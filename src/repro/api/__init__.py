"""repro.api — the unified, config-first experiment layer.

Everything an entry point needs comes from here:

* :class:`Experiment` — ``from_config`` / ``from_file`` / ``from_cli``;
  resolves every component through the registry and exposes ``train()``
  (shared :class:`TrainLoop` with full-state checkpoint/resume) and
  ``serve()`` (batched :class:`FlowSampler`).
* :class:`TrainLoop` + the :class:`Callback` protocol (``MetricLogger``,
  ``JSONLogSink``, ``PeriodicCheckpoint``, ``EarlyStop``).
* :func:`apply_overrides` — dotted ``--set flow.eta=0.5`` config surgery.
"""
from repro.api.experiment import Experiment, default_cli_config
from repro.api.loop import (Callback, EarlyStop, JSONLogSink, MetricLogger,
                            PeriodicCheckpoint, TrainLoop)
from repro.api.overrides import apply_overrides, parse_assignments
from repro.api.serving import FlowSampler
from repro.serving import ServingEngine

__all__ = ["Experiment", "default_cli_config", "TrainLoop", "Callback",
           "MetricLogger", "JSONLogSink", "PeriodicCheckpoint", "EarlyStop",
           "apply_overrides", "parse_assignments", "FlowSampler",
           "ServingEngine"]
