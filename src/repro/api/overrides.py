"""Dotted-path overrides for frozen nested config dataclasses.

The CLI's ``--set flow.eta=0.5 --set optim.lr=3e-4`` flags (and sweep grids)
are applied here: the path walks nested dataclass fields, the raw value is
parsed as JSON when possible (so lists/dicts/bools work) and then coerced
against the declared field type by :func:`repro.config.coerce`.  Frozen
dataclasses are rebuilt bottom-up with :func:`dataclasses.replace`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, Mapping, Sequence, Tuple

from repro.config import ConfigError, coerce, field_types


def parse_value(raw: Any) -> Any:
    """JSON-decode a CLI value when possible, else keep it as a string."""
    if not isinstance(raw, str):
        return raw
    try:
        return json.loads(raw)
    except (json.JSONDecodeError, ValueError):
        return raw


def parse_assignments(pairs: Iterable[str]) -> Dict[str, Any]:
    """``["flow.eta=0.5", ...]`` -> ``{"flow.eta": 0.5, ...}``."""
    out: Dict[str, Any] = {}
    for pair in pairs:
        path, sep, raw = pair.partition("=")
        if not sep or not path:
            raise ConfigError(
                f"bad override {pair!r}: expected DOTTED.PATH=VALUE")
        out[path.strip()] = parse_value(raw)
    return out


def _set_path(cfg: Any, parts: Sequence[str], value: Any, full: str) -> Any:
    if not dataclasses.is_dataclass(cfg):
        raise ConfigError(
            f"override {full!r}: {type(cfg).__name__} has no nested field "
            f"{parts[0]!r}")
    names = {f.name for f in dataclasses.fields(cfg)}
    head = parts[0]
    if head not in names:
        raise ConfigError(
            f"override {full!r}: unknown field {head!r} on "
            f"{type(cfg).__name__}; valid fields: {sorted(names)}")
    if len(parts) == 1:
        new = coerce(value, field_types(type(cfg))[head], full)
    else:
        sub = getattr(cfg, head)
        if sub is None:
            raise ConfigError(
                f"override {full!r}: field {head!r} is None — set it to a "
                "full object first (e.g. via the config file)")
        new = _set_path(sub, parts[1:], value, full)
    return dataclasses.replace(cfg, **{head: new})


def apply_overrides(cfg: Any,
                    overrides: Mapping[str, Any] | Iterable[str]) -> Any:
    """Return a copy of ``cfg`` with every dotted override applied.

    ``overrides`` is either a mapping ``{"flow.eta": 0.5}`` or an iterable of
    ``"flow.eta=0.5"`` assignment strings.
    """
    if not isinstance(overrides, Mapping):
        overrides = parse_assignments(overrides)
    for path, value in overrides.items():
        cfg = _set_path(cfg, path.split("."), value, path)
    return cfg


def replace_fields(obj: Any, mapping: Mapping[str, Any]) -> Any:
    """Typed ``dataclasses.replace`` from a plain dict (used for
    ``RunConfig.arch_overrides`` on the resolved ArchConfig)."""
    if not mapping:
        return obj
    hints = field_types(type(obj))
    names = {f.name for f in dataclasses.fields(obj)}
    unknown = sorted(set(mapping) - names)
    if unknown:
        raise ConfigError(
            f"arch_overrides: unknown field(s) {unknown} on "
            f"{type(obj).__name__}; valid fields: {sorted(names)}")
    coerced = {k: coerce(parse_value(v), hints[k], k)
               for k, v in mapping.items()}
    return dataclasses.replace(obj, **coerced)
