"""Serving side of the Experiment front door: batched flow-matching
sampling over any registered backbone × scheduler combination.

``FlowSampler`` (moved here from ``launch/serve.py``) micro-batches prompt
requests through a jit'd rollout; ``launch/serve.py`` and the serving
example are thin wrappers over :meth:`repro.api.Experiment.build_sampler`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import schedulers
from repro.core.rollout import rollout
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter


class FlowSampler:
    """Batched sampling server over a FlowAdapter."""

    def __init__(self, arch_cfg, flow_cfg, *, key, max_batch: int = 8,
                 cond_dim: int = 512, params=None):
        self.adapter = FlowAdapter(arch_cfg, flow_cfg, cond_dim)
        self.scheduler = schedulers.build(flow_cfg.sde_type, flow_cfg.eta)
        self.flow_cfg = flow_cfg
        self.params = (params if params is not None
                       else params_lib.init(self.adapter.spec(), key))
        self.max_batch = max_batch
        self._rollout = jax.jit(
            lambda p, cond, k: rollout(self.adapter, p, cond, k,
                                       self.scheduler, flow_cfg.num_steps))

    def serve(self, cond: jax.Array, key: jax.Array) -> jax.Array:
        """cond: (N, Lc, D) -> latents (N, Lt, ld); micro-batched."""
        outs = []
        N = cond.shape[0]
        for i in range(0, N, self.max_batch):
            chunk = cond[i:i + self.max_batch]
            pad = self.max_batch - chunk.shape[0]
            if pad:
                chunk = jnp.pad(chunk, ((0, pad), (0, 0), (0, 0)))
            traj = self._rollout(self.params, chunk,
                                 jax.random.fold_in(key, i))
            outs.append(traj.x0[:chunk.shape[0] - pad if pad else None])
        return jnp.concatenate(outs, axis=0)[:N]
