"""Serving side of the Experiment front door.

``FlowSampler`` is now a thin client of :class:`repro.serving.ServingEngine`
(the bucketed continuous-batching engine): it owns params + adapter +
scheduler resolution and delegates every batch to the engine, so the
historical ``serve(cond, key)`` call sites keep working while gaining
bucketed batching, compile-cache warmup, and (with a mesh) sharded
inference — data-sharded requests, and model-sharded params when the mesh
has a "model" axis (the engine self-builds the PartitionPlan from the
adapter spec).  Per-request keys are ``fold_in(key, i)`` — request i's latent
is identical whatever ``max_batch``, bucket layout, or device count is in
effect.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.core import schedulers
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter
from repro.serving import ServingEngine


class FlowSampler:
    """Batched sampling server over a FlowAdapter (engine-backed)."""

    def __init__(self, arch_cfg, flow_cfg, *, key, max_batch: int = 8,
                 cond_dim: int = 512, params=None,
                 buckets: Optional[Sequence[int]] = None,
                 step_tiers: Optional[Sequence[int]] = None,
                 deadline_s: float = 0.005, admission=None,
                 max_inflight: int = 4, mesh=None, provider=None,
                 cond_len: int = 16):
        self.adapter = FlowAdapter(arch_cfg, flow_cfg, cond_dim)
        self.scheduler = schedulers.build(flow_cfg.sde_type, flow_cfg.eta)
        self.flow_cfg = flow_cfg
        self.params = (params if params is not None
                       else params_lib.init(self.adapter.spec(), key))
        self.max_batch = max_batch
        self.engine = ServingEngine(
            self.adapter, self.scheduler, self.params,
            num_steps=flow_cfg.num_steps, max_batch=max_batch,
            buckets=buckets, step_tiers=step_tiers, deadline_s=deadline_s,
            admission=admission, max_inflight=max_inflight, mesh=mesh,
            provider=provider, cond_len=cond_len)

    def warmup(self) -> dict:
        """Pre-trace the engine's bucket grid; returns per-shape seconds."""
        return self.engine.warmup()

    def serve(self, cond: jax.Array, key: jax.Array) -> jax.Array:
        """cond: (N, Lc, D) -> latents (N, Lt, ld), bucket-batched through
        the engine."""
        return self.engine.serve(cond, key)
