"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape × mesh)
dry-run combination — no device allocation anywhere.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro import sharding as shlib
from repro.config import ArchConfig, InputShape, INPUT_SHAPES, OptimConfig
from repro.models import params as params_lib
from repro.models import tasks
from repro.models.backbone import Backbone

BF16 = jnp.bfloat16
F32 = jnp.float32
I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shard_or_none(mesh: Mesh, axes, rules) -> NamedSharding:
    return NamedSharding(mesh, shlib.pspec(axes, rules))


def _divisible(n: int, mesh: Mesh, names) -> bool:
    size = 1
    for a in (names if isinstance(names, tuple) else (names,)):
        if a in mesh.axis_names:
            size *= mesh.shape[a]
    return n % size == 0


# ---------------------------------------------------------------------------
# Parameter / optimizer / cache specs
# ---------------------------------------------------------------------------

INFERENCE_FSDP_THRESHOLD = 10e9   # bytes/device above which inference
                                  # weights also shard over the data axis
                                  # (weight-gathered serving mode)


def param_specs(cfg: ArchConfig, mesh: Mesh, *, train: bool, fsdp: bool = True
                ) -> Tuple[Any, Any]:
    """Returns (ShapeDtypeStruct tree, NamedSharding tree) for params.

    Inference (train=False): weights shard over "model" only, unless the
    model doesn't fit a device that way — then the data axis is used too
    (per-layer all-gather at use; memory-first serving for 100B+ models)."""
    if not train and not fsdp:
        model_shards = mesh.shape.get("model", 1)
        if 2.0 * cfg.n_params() / model_shards > INFERENCE_FSDP_THRESHOLD:
            fsdp = True
    spec = Backbone(cfg).spec()
    shapes = params_lib.shape_tree(spec, BF16)
    axes = params_lib.axes_tree(spec)
    rules = shlib.param_rules(mesh, fsdp=fsdp, train=train)

    def to_shard(ax_tuple, shape_struct):
        # drop shardings that don't divide (XLA would pad params — avoid for
        # the fsdp axis where padding wastes real memory)
        specs = []
        for ax, dim in zip(ax_tuple, shape_struct.shape):
            m = rules.get(ax) if ax else None
            if m is not None and not _divisible(dim, mesh, m):
                m = None
            specs.append(m)
        return NamedSharding(mesh, PartitionSpec(*specs))

    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    shardings = jax.tree.map(to_shard, axes, shapes, is_leaf=is_ax)
    return shapes, shardings


def train_state_specs(cfg: ArchConfig, mesh: Mesh, *, fsdp: bool = True):
    from repro import optim
    p_shapes, p_shard = param_specs(cfg, mesh, train=True, fsdp=fsdp)
    f32 = lambda s: _sds(s.shape, F32)
    state_shapes = tasks.TrainState(
        params=p_shapes,
        opt=optim.AdamWState(step=_sds((), I32),
                             mu=jax.tree.map(f32, p_shapes),
                             nu=jax.tree.map(f32, p_shapes)))
    state_shard = tasks.TrainState(
        params=p_shard,
        opt=optim.AdamWState(
            step=NamedSharding(mesh, PartitionSpec()),
            mu=p_shard, nu=p_shard))
    return state_shapes, state_shard


def cache_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh, *,
                seq_shard: bool = True):
    batch = shape.global_batch
    cache_len = tasks.effective_cache_len(cfg, shape)
    model = Backbone(cfg)
    spec_tree = model.cache_specs(batch, cache_len)
    # shard cache seq over data only when the batch can't use the data axis
    b_ax = shlib.batch_axes(mesh)
    batch_shardable = _divisible(batch, mesh, b_ax)
    rules = shlib.act_rules(mesh, seq_shard=seq_shard and not batch_shardable)
    if not batch_shardable:
        rules["batch"] = None
    # §Perf knob: shard decode caches' sequence dim over the model axis
    # (sequence-sharded flash-decode — memory-capacity lever for 100B+
    # models whose 32k KV cache exceeds HBM even batch-sharded)
    if os.environ.get("REPRO_CACHE_SEQ_SHARD"):
        rules["cache_seq"] = os.environ["REPRO_CACHE_SEQ_SHARD"]

    def leaf(sa):
        shp, axes = sa
        specs = []
        for ax, dim in zip(axes, shp):
            m = rules.get(ax) if ax else None
            if m is not None and not _divisible(dim, mesh, m):
                m = None
            specs.append(m)
        return (_sds(shp, BF16), NamedSharding(mesh, PartitionSpec(*specs)))

    is_sa = lambda x: (isinstance(x, tuple) and len(x) == 2
                       and isinstance(x[0], tuple)
                       and all(isinstance(d, int) for d in x[0]))
    both = jax.tree.map(leaf, spec_tree, is_leaf=is_sa)
    shapes = jax.tree.map(lambda t: t[0], both,
                          is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                          and isinstance(x[0], jax.ShapeDtypeStruct))
    shards = jax.tree.map(lambda t: t[1], both,
                          is_leaf=lambda x: isinstance(x, tuple) and len(x) == 2
                          and isinstance(x[0], jax.ShapeDtypeStruct))
    return shapes, shards


# ---------------------------------------------------------------------------
# input_specs — every model input as ShapeDtypeStruct (the dry-run contract)
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh
                ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Batch inputs for the step kind of ``shape``.

    Returns (shapes, shardings) dicts; training adds labels, vlm/audio adds
    the stub-frontend prefix embeddings (assignment carve-out)."""
    B, S = shape.global_batch, shape.seq_len
    b_ax = shlib.batch_axes(mesh)
    batch_ok = _divisible(B, mesh, b_ax)
    bspec = b_ax if (b_ax and batch_ok) else None

    def sh(*axes):
        return NamedSharding(mesh, PartitionSpec(*axes))

    if shape.kind == "train":
        shapes = {"tokens": _sds((B, S), I32), "labels": _sds((B, S), I32)}
        shards = {"tokens": sh(bspec, None), "labels": sh(bspec, None)}
    elif shape.kind == "prefill":
        shapes = {"tokens": _sds((B, S), I32)}
        shards = {"tokens": sh(bspec, None)}
    else:  # decode: one new token against a seq_len cache
        shapes = {"token": _sds((B, 1), I32)}
        shards = {"token": sh(bspec, None)}
    if cfg.frontend.kind != "none" and shape.kind != "decode":
        fe = cfg.frontend
        shapes["prefix_embed"] = _sds((B, fe.n_tokens, fe.embed_dim), BF16)
        shards["prefix_embed"] = sh(bspec, None, None)
    return shapes, shards


# ---------------------------------------------------------------------------
# Step builders for the dry-run
# ---------------------------------------------------------------------------

def build_step(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
               opt_cfg: Optional[OptimConfig] = None):
    """Returns (jitted_fn, example_args_shapes) ready to .lower(...)."""
    opt_cfg = opt_cfg or OptimConfig()
    window = tasks.effective_window(cfg, shape)
    batch_shapes, batch_shards = input_specs(cfg, shape, mesh)
    # weight-gathered FSDP: constrain per-layer weight slices to the
    # gathered layout inside scan bodies (see sharding.py) — without this
    # the partitioner all-gathers ACTIVATIONS to global batch instead.
    # Only pays off when activations ≫ weights, i.e. TRAINING; at decode the
    # partitioner's activation-gather choice is the right one (tiny x, huge W).
    if shape.kind == "train" and not os.environ.get("REPRO_NO_WEIGHT_GATHER"):
        shlib.set_param_gather(mesh)
    else:
        shlib.set_param_gather(None)

    if shape.kind == "train":
        step = tasks.make_train_step(cfg, opt_cfg, window=window, remat=True)
        st_shapes, st_shards = train_state_specs(cfg, mesh)
        fn = jax.jit(step, in_shardings=(st_shards, batch_shards),
                     out_shardings=(st_shards, None), donate_argnums=0)
        return fn, (st_shapes, batch_shapes)

    if shape.kind == "prefill":
        step = tasks.make_prefill_step(cfg, window=window)
        p_shapes, p_shards = param_specs(cfg, mesh, train=False, fsdp=False)
        fn = jax.jit(step, in_shardings=(p_shards, batch_shards))
        return fn, (p_shapes, batch_shapes)

    # decode
    step = tasks.make_decode_step(cfg, window=window)
    p_shapes, p_shards = param_specs(cfg, mesh, train=False, fsdp=False)
    c_shapes, c_shards = cache_specs(cfg, shape, mesh)
    pos_shape = _sds((), I32)
    pos_shard = NamedSharding(mesh, PartitionSpec())
    fn = jax.jit(step, in_shardings=(p_shards, c_shards,
                                     batch_shards["token"], pos_shard),
                 donate_argnums=1)      # ring-buffer cache updates in place
    return fn, (p_shapes, c_shapes, batch_shapes["token"], pos_shape)


# ---------------------------------------------------------------------------
# Flow-RL (paper pipeline) dry-run step: one GRPO update on trajectories
# ---------------------------------------------------------------------------

def build_flow_step(cfg: ArchConfig, mesh: Mesh, *,
                    num_steps: int = 10, latent_tokens: int = 1024,
                    latent_dim: int = 16, cond_len: int = 16,
                    cond_dim: int = 512, group_size: int = 8,
                    prompts: int = 32):
    """The paper's own training step (Flow-GRPO update) at production scale:
    lowered for the representative archs in the §Perf hillclimb."""
    from repro.config import FlowRLConfig
    from repro.core.trainers.grpo import FlowGRPOTrainer

    flow_cfg = FlowRLConfig(num_steps=num_steps, group_size=group_size,
                            latent_tokens=latent_tokens, latent_dim=latent_dim)
    opt_cfg = OptimConfig()
    if os.environ.get("REPRO_NO_WEIGHT_GATHER"):
        shlib.set_param_gather(None)
    else:
        shlib.set_param_gather(mesh)
    B = prompts * group_size
    trainer = FlowGRPOTrainer.__new__(FlowGRPOTrainer)
    # build without allocating params (dry-run only)
    from repro.core import schedulers
    from repro.models.flow import FlowAdapter
    trainer.cfg = cfg
    trainer.flow = flow_cfg
    trainer.opt_cfg = opt_cfg
    trainer.adapter = FlowAdapter(cfg, flow_cfg, cond_dim)
    trainer.scheduler = schedulers.build(flow_cfg.sde_type, flow_cfg.eta)
    from repro import optim
    trainer._lr = optim.make_schedule(opt_cfg)

    spec = trainer.adapter.spec()
    p_shapes = params_lib.shape_tree(spec, BF16)
    axes = params_lib.axes_tree(spec)
    rules = shlib.param_rules(mesh, fsdp=True, train=True)

    def to_shard(ax_tuple, shape_struct):
        specs = []
        for ax, dim in zip(ax_tuple, shape_struct.shape):
            m = rules.get(ax) if ax else None
            if m is not None and not _divisible(dim, mesh, m):
                m = None
            specs.append(m)
        return NamedSharding(mesh, PartitionSpec(*specs))

    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    p_shards = jax.tree.map(to_shard, axes, p_shapes, is_leaf=is_ax)

    from repro.core.rollout import Trajectory
    from repro.core.trainers.base import RLState
    b_ax = shlib.batch_axes(mesh)
    T = num_steps
    traj_shapes = Trajectory(
        xs=_sds((T + 1, B, latent_tokens, latent_dim), F32),
        logps=_sds((T, B), F32),
        ts=_sds((T + 1,), F32),
        sde_mask=_sds((T,), jnp.bool_),
        cond=_sds((B, cond_len, cond_dim), F32))
    rep = NamedSharding(mesh, PartitionSpec())
    bsh = NamedSharding(mesh, PartitionSpec(None, b_ax))
    traj_shards = Trajectory(
        xs=NamedSharding(mesh, PartitionSpec(None, b_ax, None, None)),
        logps=bsh, ts=rep, sde_mask=rep,
        cond=NamedSharding(mesh, PartitionSpec(b_ax, None, None)))
    adv_shapes = _sds((B,), F32)
    adv_shards = NamedSharding(mesh, PartitionSpec(b_ax))
    key_shapes = _sds((2,), jnp.uint32)

    from repro import optim as optim_lib
    st_shapes = RLState(
        params=p_shapes,
        opt=optim_lib.AdamWState(
            step=_sds((), I32),
            mu=jax.tree.map(lambda s: _sds(s.shape, F32), p_shapes),
            nu=jax.tree.map(lambda s: _sds(s.shape, F32), p_shapes)))
    st_shards = RLState(params=p_shards,
                        opt=optim_lib.AdamWState(step=rep, mu=p_shards,
                                                 nu=p_shards))

    fn = jax.jit(trainer._update,
                 in_shardings=(st_shards, traj_shards, adv_shards, rep),
                 out_shardings=(st_shards, None), donate_argnums=0)
    return fn, (st_shapes, traj_shapes, adv_shapes, key_shapes)
