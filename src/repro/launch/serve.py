"""Flow-matching sampling service — a thin shell over the Experiment API.

Requests are micro-batched through :class:`repro.api.FlowSampler`; backbone
and solver are registry names, so any registered combination serves.

  PYTHONPATH=src python -m repro.launch.serve --arch flux_dit --reduced \\
      --sde ode --requests 16 --set flow.num_steps=8
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.api import Experiment, FlowSampler  # noqa: F401 (re-export)
from repro.api.experiment import default_cli_config
from repro.config import replace


def serve_profile():
    """Serving defaults: deterministic ODE solver, small latent geometry."""
    cfg = default_cli_config()
    return replace(cfg, flow=replace(cfg.flow, sde_type="ode", eta=0.3))


def main(argv=None) -> None:
    ap = Experiment.cli_parser("Flow-Factory sampling service")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    exp = Experiment.from_args(args, base=serve_profile())

    from repro.data import synthetic_prompts
    prompts = synthetic_prompts(args.requests)
    t0 = time.time()
    latents = exp.serve(prompts, max_batch=args.max_batch)
    dt = time.time() - t0
    print(f"served {args.requests} requests in {dt:.2f}s "
          f"({args.requests/dt:.1f} req/s); latents {latents.shape}, "
          f"rms={float(jnp.sqrt((latents**2).mean())):.3f}")
    assert np.isfinite(np.asarray(latents)).all()


if __name__ == "__main__":
    main()
