"""Flow-matching sampling service — a thin shell over the serving engine.

Requests go through :class:`repro.serving.ServingEngine` (bucketed
continuous batching, compile-cache warmup, LRU cond cache, sharded
inference); backbone and solver are registry names, so any registered
combination serves.  Compile time and steady-state throughput are reported
*separately* — the warmup pass pre-traces the bucket grid and is excluded
from the serve timing.

  PYTHONPATH=src python -m repro.launch.serve --arch flux_dit --reduced \\
      --sde ode --requests 16 --set flow.num_steps=8

  # 4-way sharded serving on faked CPU devices (bit-identical per request
  # to single-device):
  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      python -m repro.launch.serve --reduced --requests 32 \\
      --set dist.data_parallel=4
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.api import Experiment, FlowSampler  # noqa: F401 (re-export)
from repro.api.experiment import default_cli_config
from repro.config import replace


def serve_profile():
    """Serving defaults: deterministic ODE solver, small latent geometry."""
    cfg = default_cli_config()
    return replace(cfg, flow=replace(cfg.flow, sde_type="ode", eta=0.3))


def main(argv=None) -> None:
    ap = Experiment.cli_parser("Flow-Factory sampling service")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--bucket", default="", metavar="B1,B2,...",
                    help="comma-separated batch bucket tiers "
                         "(default: powers of two up to --max-batch)")
    ap.add_argument("--deadline-ms", type=float, default=5.0,
                    help="max wait before a partial bucket is flushed")
    ap.add_argument("--step-tiers", default="", metavar="S1,S2,...",
                    help="admitted num_steps quality tiers (warmed and "
                         "enforced at submit; default: flow.num_steps only)")
    ap.add_argument("--stats-json", default="", metavar="PATH",
                    help="write the engine's JSON stats/health snapshot "
                         "to PATH after serving ('-' prints to stdout)")
    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error("--requests must be >= 1")
    if args.max_batch < 1:
        ap.error("--max-batch must be >= 1")
    try:
        buckets = ([int(b) for b in args.bucket.split(",") if b]
                   if args.bucket else None)
        if buckets and any(b < 1 for b in buckets):
            raise ValueError(f"bucket sizes must be >= 1, got {buckets}")
    except ValueError as e:
        ap.error(f"--bucket: {e}")
    try:
        step_tiers = ([int(s) for s in args.step_tiers.split(",") if s]
                      if args.step_tiers else None)
        if step_tiers and any(s < 1 for s in step_tiers):
            raise ValueError(f"step tiers must be >= 1, got {step_tiers}")
    except ValueError as e:
        ap.error(f"--step-tiers: {e}")
    exp = Experiment.from_args(args, base=serve_profile())

    from repro.data import synthetic_prompts
    prompts = synthetic_prompts(args.requests)
    key = jax.random.PRNGKey(exp.cfg.seed)
    engine = exp.build_engine(key, max_batch=args.max_batch, buckets=buckets,
                              step_tiers=step_tiers,
                              deadline_s=args.deadline_ms / 1e3)

    # warmup: pre-trace the bucket grid and prime the cond encoder; both are
    # reported separately so the serve timing below is pure steady state
    # (the historical report timed a warm jit cache over a ~0s region and
    # printed "inf req/s")
    t0 = time.perf_counter()
    report = engine.warmup()
    warm_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    engine.encode(prompts)               # encoder compile + cond-cache fill
    enc_s = time.perf_counter() - t0
    grid = " ".join(f"{k}={v:.2f}s" for k, v in sorted(report.items()))
    print(f"warmup: traced {len(report)} bucket shapes in {warm_s:.2f}s "
          f"({grid}); cond encode+cache {enc_s:.2f}s")

    t0 = time.perf_counter()
    latents = engine.serve(prompts, key)
    jax.block_until_ready(latents)
    dt = max(time.perf_counter() - t0, 1e-9)
    s = engine.stats
    # one transfer, reused for the rms report and the finite check —
    # float(jnp.sqrt(...)) here would force a second device round-trip
    # after block_until_ready (jaxlint R002)
    lat = np.asarray(latents)
    print(f"steady-state: served {args.requests} requests in {dt:.3f}s "
          f"({args.requests/dt:.1f} req/s); latents {latents.shape}, "
          f"rms={float(np.sqrt((lat**2).mean())):.3f}")
    print(f"engine: buckets={s['buckets']} step_tiers={s['step_tiers']} "
          f"dp={s['data_parallel']} dispatches={s['dispatches']} "
          f"padded_lanes={s['padded_lanes']} "
          f"cold_dispatches={s['cold_dispatches']} "
          f"cond_cache={s['cond_cache']}")
    if args.stats_json:
        payload = json.dumps(s, indent=2, sort_keys=True)
        if args.stats_json == "-":
            print(payload)
        else:
            with open(args.stats_json, "w") as f:
                f.write(payload + "\n")
            print(f"stats: wrote JSON snapshot to {args.stats_json}")
    assert s["cold_dispatches"] == 0, "steady-state serve hit a compile"
    assert np.isfinite(lat).all()


if __name__ == "__main__":
    main()
