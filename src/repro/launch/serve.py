"""Flow-matching sampling service: batched prompt requests → latents.

Demonstrates the serving side of the framework: condition embeddings come
from the preprocessing cache (or a live encoder), sampling runs any
registered SDE/ODE scheduler, and requests are micro-batched.

  PYTHONPATH=src python -m repro.launch.serve --arch flux_dit --reduced \\
      --sde ode --num-steps 8 --requests 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import FlowRLConfig
from repro.core import schedulers
from repro.core.preprocess import ConditionProvider
from repro.core.rollout import rollout
from repro.data import synthetic_prompts
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter


class FlowSampler:
    """Batched sampling server over a FlowAdapter."""

    def __init__(self, arch_cfg, flow_cfg, *, key, max_batch: int = 8):
        self.adapter = FlowAdapter(arch_cfg, flow_cfg)
        self.scheduler = schedulers.build(flow_cfg.sde_type, flow_cfg.eta)
        self.flow_cfg = flow_cfg
        self.params = params_lib.init(self.adapter.spec(), key)
        self.max_batch = max_batch
        self._rollout = jax.jit(
            lambda p, cond, k: rollout(self.adapter, p, cond, k,
                                       self.scheduler, flow_cfg.num_steps))

    def serve(self, cond: jax.Array, key: jax.Array) -> jax.Array:
        """cond: (N, Lc, D) -> latents (N, Lt, ld); micro-batched."""
        outs = []
        N = cond.shape[0]
        for i in range(0, N, self.max_batch):
            chunk = cond[i:i + self.max_batch]
            pad = self.max_batch - chunk.shape[0]
            if pad:
                chunk = jnp.pad(chunk, ((0, pad), (0, 0), (0, 0)))
            traj = self._rollout(self.params, chunk,
                                 jax.random.fold_in(key, i))
            outs.append(traj.x0[:chunk.shape[0] - pad if pad else None])
        return jnp.concatenate(outs, axis=0)[:N]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flux_dit",
                    choices=configs.ARCH_IDS + configs.PAPER_ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--sde", default="ode")
    ap.add_argument("--eta", type=float, default=0.3)
    ap.add_argument("--num-steps", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=8)
    args = ap.parse_args()

    arch_cfg = (configs.get_reduced(args.arch) if args.reduced
                else configs.get(args.arch))
    flow_cfg = FlowRLConfig(sde_type=args.sde, eta=args.eta,
                            num_steps=args.num_steps, latent_tokens=16,
                            latent_dim=8)
    key = jax.random.PRNGKey(0)
    sampler = FlowSampler(arch_cfg, flow_cfg, key=key,
                          max_batch=args.max_batch)
    provider = ConditionProvider(preprocessing=False,
                                 encoder_kw=dict(cond_dim=512, cond_len=16))

    prompts = synthetic_prompts(args.requests)
    t0 = time.time()
    cond = provider.get(prompts)["cond"]
    latents = sampler.serve(cond, key)
    dt = time.time() - t0
    print(f"served {args.requests} requests in {dt:.2f}s "
          f"({args.requests/dt:.1f} req/s); latents {latents.shape}, "
          f"rms={float(jnp.sqrt((latents**2).mean())):.3f}")
    assert np.isfinite(np.asarray(latents)).all()


if __name__ == "__main__":
    main()
