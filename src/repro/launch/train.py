"""Flow-Factory training launcher — the paper's end-to-end driver.

Phases (paper §2.2 two-phase design):
  1. preprocess: encode every prompt once, cache to disk, frozen encoders
     are then offloaded (never instantiated again).
  2. train: <trainer_type> RL fine-tuning of the selected backbone against
     the configured rewards, checkpointing every --save-every steps.

  PYTHONPATH=src python -m repro.launch.train --arch flux_dit --reduced \\
      --trainer flow_grpo --sde flow_sde --steps 100
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro import checkpoint, configs, registry
from repro.config import FlowRLConfig, OptimConfig, RewardSpec
from repro.core.preprocess import (ConditionProvider, PreprocessCache,
                                   preprocess_dataset)
from repro.data import PromptDataset, synthetic_prompts


def build_reward_specs(names: str, latent_tokens: int, latent_dim: int):
    out = []
    for entry in names.split(","):
        name, _, w = entry.partition(":")
        args = {}
        if name in ("text_render",):
            args = {"latent_dim": latent_dim, "latent_tokens": latent_tokens}
        elif name in ("pickscore", "pref_group"):
            args = {"latent_dim": latent_dim}
        out.append(RewardSpec(name, float(w or 1.0), args=args))
    return tuple(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="flux_dit",
                    choices=configs.ARCH_IDS + configs.PAPER_ARCHS)
    ap.add_argument("--reduced", action="store_true",
                    help="use the ≤2-layer reduced config (CPU-runnable)")
    ap.add_argument("--trainer", default="flow_grpo",
                    choices=["flow_grpo", "mix_grpo", "grpo_guard", "nft",
                             "awm"])
    ap.add_argument("--sde", default="flow_sde",
                    choices=["flow_sde", "dance_sde", "cps", "ode"])
    ap.add_argument("--eta", type=float, default=0.7)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--prompts", type=int, default=64)
    ap.add_argument("--batch-prompts", type=int, default=4)
    ap.add_argument("--group-size", type=int, default=4)
    ap.add_argument("--num-steps", type=int, default=8)
    ap.add_argument("--latent-tokens", type=int, default=16)
    ap.add_argument("--latent-dim", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--rewards", default="text_render:1.0")
    ap.add_argument("--agg", default="weighted_sum",
                    choices=["weighted_sum", "gdpo"])
    ap.add_argument("--no-preprocessing", action="store_true",
                    help="paper Table 2 baseline: re-encode every step")
    ap.add_argument("--cache-dir", default="cache")
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-file", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch_cfg = (configs.get_reduced(args.arch) if args.reduced
                else configs.get(args.arch))
    flow_cfg = FlowRLConfig(
        trainer_type=args.trainer, sde_type=args.sde, eta=args.eta,
        num_steps=args.num_steps, group_size=args.group_size,
        latent_tokens=args.latent_tokens, latent_dim=args.latent_dim,
        advantage_agg=args.agg,
        rewards=build_reward_specs(args.rewards, args.latent_tokens,
                                   args.latent_dim),
        preprocessing=not args.no_preprocessing, cache_dir=args.cache_dir)
    opt_cfg = OptimConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(2, args.steps // 20))

    key = jax.random.PRNGKey(args.seed)
    prompts = synthetic_prompts(args.prompts, seed=args.seed)

    # ---- phase 1: preprocessing ----
    t0 = time.time()
    if flow_cfg.preprocessing:
        cache = PreprocessCache(args.cache_dir)
        n = preprocess_dataset(prompts, cache)
        provider = ConditionProvider(preprocessing=True, cache=cache)
        print(f"[preprocess] cached {n} new prompts in "
              f"{time.time()-t0:.1f}s; frozen encoders offloaded")
    else:
        provider = ConditionProvider(preprocessing=False)
        print("[preprocess] DISABLED — encoders stay resident (baseline)")

    # ---- phase 2: RL training ----
    trainer = registry.build("trainer", args.trainer, arch_cfg, flow_cfg,
                             opt_cfg, key=key)
    print(f"[train] {args.trainer} on {arch_cfg.name} "
          f"({arch_cfg.n_params()/1e6:.1f}M params), sde={args.sde}, "
          f"rewards={[s.reward_type for s in flow_cfg.rewards]} "
          f"(unique loads: {trainer.loader.unique_loads})")

    ds = PromptDataset(prompts, batch_size=args.batch_prompts,
                       seed=args.seed)
    log = []
    t_train = time.time()
    for it, batch_prompts in zip(range(args.steps), ds.infinite()):
        t_it = time.time()
        cond = provider.get(batch_prompts)["cond"]
        m = trainer.step(cond, key, it=it)
        row = {"step": it, "reward": float(m["reward_mean"]),
               "loss": float(m["loss"]),
               "grad_norm": float(m["grad_norm"]),
               "encode_resident": provider.encoder_resident,
               "dt": round(time.time() - t_it, 3)}
        log.append(row)
        if it % 10 == 0 or it == args.steps - 1:
            print(f"  step {it:4d}  reward={row['reward']:+.4f}  "
                  f"loss={row['loss']:+.4f}  dt={row['dt']:.2f}s")
        if args.save_every and (it + 1) % args.save_every == 0:
            checkpoint.save_checkpoint(args.ckpt_dir, it + 1,
                                       trainer.state.params)
    print(f"[train] {args.steps} steps in {time.time()-t_train:.1f}s; "
          f"reward {log[0]['reward']:+.4f} -> {log[-1]['reward']:+.4f}")
    if args.log_file:
        os.makedirs(os.path.dirname(args.log_file) or ".", exist_ok=True)
        with open(args.log_file, "w") as f:
            json.dump(log, f)


if __name__ == "__main__":
    main()
