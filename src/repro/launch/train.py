"""Flow-Factory training launcher — a thin shell over the Experiment API.

One declarative :class:`RunConfig` drives both phases (paper §2.2):
preprocess-and-cache the prompt corpus, then RL fine-tune the selected
backbone via the shared :class:`repro.api.TrainLoop` with full-state
checkpointing (params + optimizer) and auto-resume.

Everything is config: pass a JSON file and/or dotted overrides — the
convenience flags (``--arch/--trainer/--sde``) derive their choices from
the registry, so they can never drift from what is registered.

  PYTHONPATH=src python -m repro.launch.train --reduced --steps 2
  PYTHONPATH=src python -m repro.launch.train --config run.json \\
      --set flow.eta=0.5 --set optim.lr=3e-4 --set loop.log_file=log.json

Distributed training runs on a 2-D (data × model) device mesh: prompt×group
batches shard over the "data" axis, params/optimizer moments over the
"model" axis per the PartitionPlan, with optional gradient-accumulation
microbatching (``repro.distributed``); on CPU, host devices are faked via
XLA_FLAGS:

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      python -m repro.launch.train --reduced --steps 2 \\
      --set dist.data_parallel=4 --set dist.microbatch=2

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=4 \\
      python -m repro.launch.train --reduced --steps 2 \\
      --set dist.data_parallel=2 --set dist.model_parallel=2

The equivalent programmatic path is ``Experiment.from_file("run.json")``
(see ROADMAP.md "Running experiments").
"""
from __future__ import annotations

import jax

from repro.api import Experiment
from repro.distributed import resolve_axes


def main(argv=None) -> None:
    exp = Experiment.from_cli(argv)
    d = exp.describe()
    dp, mp = resolve_axes(exp.cfg.dist)
    print(f"[train] {d['trainer']['name']} on {d['arch']['name']} "
          f"({d['arch']['n_params']/1e6:.1f}M params), "
          f"sde={d['scheduler']['name']}, rewards={d['rewards']}")
    print(f"[train] devices={jax.local_device_count()} data_parallel={dp} "
          f"model_parallel={mp} microbatch={exp.cfg.dist.microbatch or 1}")
    p = exp.cfg.perf
    if exp.cfg.loop.pipeline != 1:
        print(f"[perf] loop.pipeline={exp.cfg.loop.pipeline} "
              "(metrics drain up to pipeline-1 steps late; computation "
              "is unchanged)")
    if p != type(p)():
        print(f"[perf] remat={p.remat} fuse_step={p.fuse_step}"
              + (f" policy_dtype={p.policy_dtype}" if p.policy_dtype else "")
              + (" offload_rewards=true" if p.offload_rewards else "")
              + (" remat_offload=true" if p.remat_offload else ""))
    if p.log_memory:
        tr = exp.build_trainer()
        d_cfg = exp.cfg.data
        cond = jax.ShapeDtypeStruct(
            (d_cfg.batch_prompts, exp.cond_len, exp.cond_dim),
            jax.numpy.float32)
        for name, mem in tr.memory_stats(cond).items():
            # analysis_dict degrades to {"error": str} on backends without
            # memory_analysis support — report, don't crash the launch
            pretty = " ".join(
                f"{k[:-len('_bytes')]}={v / 1e6:.2f}MB"
                if k.endswith("_bytes") and isinstance(v, (int, float))
                else f"{k}={v}"
                for k, v in mem.items() if v is not None)
            print(f"[perf] {name} memory_analysis: {pretty}")
    result = exp.train()
    hist = result["history"]
    if hist:
        print(f"[train] steps {result['start_step']}..{result['final_step']}"
              f"; reward {hist[0]['reward']:+.4f} -> {hist[-1]['reward']:+.4f}")


if __name__ == "__main__":
    main()
