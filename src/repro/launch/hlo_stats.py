"""HLO-text analysis: collective traffic, loop-aware.

``cost_analysis()`` on the CPU backend counts ``while`` (lax.scan) bodies
ONCE, independent of trip count — useless for scanned-layer models.  This
parser walks the computation graph of the compiled (post-SPMD) HLO:

* splits the module into computations,
* recursively expands ``while`` bodies multiplied by their trip count
  (recovered from the loop-condition's comparison constant),
* for every all-gather / all-reduce / reduce-scatter / all-to-all /
  collective-permute, records result bytes and converts to *link bytes moved
  per device* using the textbook ring-algorithm factors and the participant
  group size parsed from ``replica_groups``.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{")
# `%name = <result-type> op(...)` — result may be a tuple containing layout
# braces and /*index=N*/ comments, so locate the op as the identifier right
# before the first '(' that FOLLOWS the result type instead.
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*")
_OP_RE = re.compile(r"([\w\-]+)\(")


def parse_instr(line: str):
    """Returns (op, result_text) or None."""
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    rest = line[m.end():]
    if rest.startswith("("):           # tuple result: find matching paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    result = rest[:i + 1]
                    tail = rest[i + 1:]
                    break
        else:
            return None
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        result = rest[:sp]
        tail = rest[sp:]
    om = _OP_RE.search(tail)
    if not om:
        return None
    return om.group(1), result
_CALLED_RE = re.compile(r"(condition|body|to_apply|branch_computations)="
                        r"\{?%?([\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.groups()
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _moved_bytes(kind: str, result_bytes: int, g: int) -> float:
    """Per-device link traffic (ring algorithms)."""
    if g <= 1:
        return 0.0
    if kind == "all-gather":
        return result_bytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if kind == "reduce-scatter":
        return result_bytes * (g - 1)          # operand = result × g
    if kind == "all-to-all":
        return result_bytes * (g - 1) / g
    if kind == "collective-permute":
        return float(result_bytes)
    return 0.0


def split_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    buf: List[str] = []
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m and "{" in line:
                cur = m.group(1)
                buf = []
        else:
            if line.startswith("}") or line.strip() == "}":
                comps[cur] = buf
                cur = None
            else:
                buf.append(line)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Heuristic: largest s32 scalar constant in the loop condition."""
    consts = [int(m.group(1)) for line in cond_lines
              for m in _CONST_RE.finditer(line)]
    return max(consts) if consts else 1


class HloAnalysis:
    def __init__(self, hlo: str):
        self.comps = split_computations(hlo)
        self.entry = None
        for line in hlo.splitlines():
            if line.startswith("ENTRY"):
                m = _COMP_HDR_RE.match(line)
                if m:
                    self.entry = m.group(1)
        if self.entry is None:           # fall back: last computation
            self.entry = list(self.comps)[-1] if self.comps else ""
        self._memo: Dict[str, Dict] = {}

    def _analyze(self, comp: str) -> Dict:
        if comp in self._memo:
            return self._memo[comp]
        stats = {k: {"count": 0.0, "result_bytes": 0.0, "moved_bytes": 0.0}
                 for k in COLLECTIVES}
        ops: Dict[str, float] = defaultdict(float)
        self._memo[comp] = {"coll": stats, "ops": ops}  # break cycles
        for line in self.comps.get(comp, ()):
            parsed = parse_instr(line)
            if not parsed:
                continue
            op, result = parsed
            ops[op] += 1
            if op == "while":
                called = dict((k, v) for k, v in _CALLED_RE.findall(line))
                body = called.get("body")
                cond = called.get("condition")
                trip = _trip_count(self.comps.get(cond, [])) if cond else 1
                if body:
                    sub = self._analyze(body)
                    for k in COLLECTIVES:
                        for f in stats[k]:
                            stats[k][f] += trip * sub["coll"][k][f]
                    for o, c in sub["ops"].items():
                        ops[o] += trip * c
                continue
            if op in ("call", "conditional"):
                for _, callee in _CALLED_RE.findall(line):
                    sub = self._analyze(callee)
                    for k in COLLECTIVES:
                        for f in stats[k]:
                            stats[k][f] += sub["coll"][k][f]
                continue
            base = None
            for k in COLLECTIVES:
                if op == k or op == k + "-start":
                    base = k
                    break
            if base is None:
                continue
            rb = _shape_bytes(result)
            g = _group_size(line)
            stats[base]["count"] += 1
            stats[base]["result_bytes"] += rb
            stats[base]["moved_bytes"] += _moved_bytes(base, rb, g)
        return self._memo[comp]

    def collectives(self) -> Dict[str, Dict[str, float]]:
        res = self._analyze(self.entry)["coll"]
        out = {k: dict(v) for k, v in res.items()}
        out["_total"] = {
            "count": sum(v["count"] for v in res.values()),
            "result_bytes": sum(v["result_bytes"] for v in res.values()),
            "moved_bytes": sum(v["moved_bytes"] for v in res.values()),
        }
        return out

    def op_histogram(self, top: int = 30) -> Dict[str, float]:
        ops = self._analyze(self.entry)["ops"]
        return dict(sorted(ops.items(), key=lambda kv: -kv[1])[:top])


def collective_bytes(hlo: str) -> Dict[str, Dict[str, float]]:
    return HloAnalysis(hlo).collectives()


def op_histogram(hlo: str, top: int = 30) -> Dict[str, float]:
    return HloAnalysis(hlo).op_histogram(top)
