import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (architecture × input-shape)
pair on the production meshes, with NO device allocation (ShapeDtypeStruct
inputs only).  The two lines above MUST stay the first statements — jax
locks the device count on first init.

Per pair it records to experiments/dryrun/<arch>__<shape>__<mesh>.json:
  * memory_analysis()  — bytes per device (proves the config fits)
  * cost_analysis()    — HLO FLOPs / bytes accessed (roofline numerators)
  * collective traffic — parsed from the compiled HLO, per collective kind
  * wall-clock lower/compile times

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k --flow-rl
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro import configs
from repro.config import INPUT_SHAPES
from repro.launch import costs as costs_lib
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_flow_step, build_step


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            flow_rl: bool = False, out_dir: str = "experiments/dryrun",
            variant: str = "baseline") -> dict:
    cfg = configs.get(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"

    t0 = time.time()
    with mesh:
        if flow_rl:
            fn, args = build_flow_step(cfg, mesh)
        else:
            fn, args = build_step(cfg, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement every field
        mem_info = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        cost_info = {k: float(v) for k, v in cost.items()
                     if isinstance(v, (int, float))}
    except Exception as e:
        cost_info = {"error": str(e)}

    hlo = compiled.as_text()
    analysis = hlo_stats.HloAnalysis(hlo)
    coll = analysis.collectives()
    ops = analysis.op_histogram()
    analytic = (costs_lib.step_costs(cfg, shape).asdict()
                if not flow_rl else {})

    record = {
        "arch": arch,
        "shape": shape_name if not flow_rl else "flow_rl_update",
        "mesh": mesh_name,
        "variant": variant,
        "n_devices": mesh.size,
        "kind": "flow_rl" if flow_rl else shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_info,
        "cost": cost_info,
        "analytic": analytic,
        "collectives": coll,
        "op_histogram": ops,
    }

    os.makedirs(out_dir, exist_ok=True)
    tag = record["shape"]
    suffix = f"__{variant}" if variant != "baseline" else ""
    path = os.path.join(out_dir, f"{arch}__{tag}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS
                    + configs.PAPER_ARCHS)
    ap.add_argument("--shape", default="train_4k",
                    choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--flow-rl", action="store_true",
                    help="lower the paper's GRPO update step instead of the "
                         "LM step")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    try:
        rec = run_one(args.arch, args.shape, multi_pod=args.multi_pod,
                      flow_rl=args.flow_rl, out_dir=args.out_dir,
                      variant=args.variant)
    except Exception:
        traceback.print_exc()
        sys.exit(1)

    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "mesh", "compile_s", "memory",
                       "collectives")}, indent=1))


if __name__ == "__main__":
    main()
