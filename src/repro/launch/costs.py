"""Analytic per-step cost model — FLOPs and HBM traffic for every
(arch × shape) pair.

Why analytic: the CPU backend's ``cost_analysis()`` counts ``lax.scan``
bodies once regardless of trip count (verified empirically — FLOPs don't
change with layer count), so compiled-artifact FLOPs are unusable for
scanned-layer models.  We instead compute exact FLOP counts from the model
math that the HLO implements (cross-validated against ``cost_analysis()`` on
1-layer configs, where the scan-once behaviour is harmless — see
tests/test_costs.py), and pair them with the *parsed, trip-count-corrected*
collective bytes from launch.hlo_stats.

Conventions:
  * 1 MAC = 2 FLOPs; matmul FLOPs = 2·M·N·K.
  * "jnp path" attention computes the full Sq×Sk score matrix (the causal
    mask is applied, not exploited) — ``attn_flops``; the Pallas flash
    kernel skips fully-masked blocks — ``attn_flops_kernel`` (≈half for
    causal, window-bounded for sliding windows).  Both are reported.
  * backward = 2× forward; remat="block" recomputes forward once → ×4 total.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.config import ArchConfig, InputShape
from repro.models import tasks


@dataclasses.dataclass
class StepCosts:
    flops: float               # total step FLOPs (global, jnp path)
    flops_kernel: float        # ditto if the flash/SSD kernels are used
    model_flops: float         # 6·N_active·tokens (the MFU numerator)
    hbm_bytes: float           # global HBM traffic
    notes: str = ""

    def asdict(self) -> Dict[str, float]:
        return {"flops": self.flops, "flops_kernel": self.flops_kernel,
                "model_flops": self.model_flops, "hbm_bytes": self.hbm_bytes,
                "notes": self.notes}


# ---------------------------------------------------------------------------
# per-layer pieces
# ---------------------------------------------------------------------------

def _attn_matmul_params(cfg: ArchConfig) -> int:
    hd = cfg.resolved_head_dim
    if cfg.mla:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        return (cfg.d_model * m.q_lora_rank
                + m.q_lora_rank * cfg.n_heads * qk
                + cfg.d_model * (m.kv_lora_rank + m.qk_rope_head_dim)
                + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim
                                                  + m.v_head_dim)
                + cfg.n_heads * m.v_head_dim * cfg.d_model)
    return (cfg.d_model * cfg.n_heads * hd
            + 2 * cfg.d_model * cfg.n_kv_heads * hd
            + cfg.n_heads * hd * cfg.d_model)


def _ffn_matmul_params(cfg: ArchConfig, *, active: bool) -> float:
    """Per *MoE/FFN layer* active matmul params (token-averaged)."""
    if cfg.moe and cfg.moe.n_experts:
        m = cfg.moe
        router = cfg.d_model * m.n_experts
        k_eff = m.top_k + m.n_shared_experts
        experts = (k_eff if active else m.n_experts + m.n_shared_experts) \
            * 3 * cfg.d_model * m.expert_d_ff
        return router + experts
    return 3 * cfg.d_model * cfg.d_ff


def _ssm_matmul_params(cfg: ArchConfig) -> int:
    from repro.models import ssm as ssm_lib
    m = ssm_lib.dims(cfg)
    proj_out = 2 * m["d_in"] + 2 * m["N"] + m["H"]
    return cfg.d_model * proj_out + m["d_in"] * cfg.d_model


def _ssd_seq_flops(cfg: ArchConfig, n_tokens: float) -> float:
    from repro.models import ssm as ssm_lib
    m = ssm_lib.dims(cfg)
    Q, N, d_in = m["Q"], m["N"], m["d_in"]
    return 2.0 * n_tokens * (Q * N + Q * d_in + 2.0 * d_in * N)


def _attn_seq_flops(cfg: ArchConfig, B: float, Sq: float, Sk: float,
                    *, window: int, causal: bool) -> Dict[str, float]:
    """(QK + AV) FLOPs for one attention layer: jnp path vs kernel path."""
    if cfg.mla:
        qk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        v = cfg.mla.v_head_dim
    else:
        qk = v = cfg.resolved_head_dim
    full = 2.0 * B * Sq * Sk * cfg.n_heads * (qk + v)
    if window and window < Sk:
        eff = float(window)
        kernel = 2.0 * B * Sq * eff * cfg.n_heads * (qk + v)
    elif causal and Sq == Sk:
        kernel = full / 2.0
    else:
        kernel = full
    return {"full": full, "kernel": kernel}


def _mla_decode_attn_flops(cfg: ArchConfig, B: float, T: float) -> float:
    m = cfg.mla
    # absorbed path: scores in rank space + rope, output back through rank
    return 2.0 * B * T * cfg.n_heads * (m.kv_lora_rank
                                        + m.qk_rope_head_dim
                                        + m.kv_lora_rank)


# ---------------------------------------------------------------------------
# layer schedule
# ---------------------------------------------------------------------------

def _layer_counts(cfg: ArchConfig):
    """Returns (n_attn_layers, n_ffn_layers, n_dense_ffn, n_ssm_layers)."""
    if cfg.family == "ssm":
        return 0, 0, 0, cfg.n_layers
    if cfg.family == "hybrid":
        sites = cfg.n_layers // cfg.hybrid.attn_every
        return sites, sites, sites, cfg.n_layers   # shared blocks have mlp
    if cfg.family == "moe" and cfg.moe.first_k_dense:
        fk = cfg.moe.first_k_dense
        return cfg.n_layers, cfg.n_layers - fk, fk, 0
    return cfg.n_layers, 0 if cfg.family == "moe" else cfg.n_layers, \
        (cfg.n_layers if cfg.family != "moe" else 0), 0


def matmul_params_active(cfg: ArchConfig) -> float:
    """Active matmul params per token (excl. embedding gather, incl. head)."""
    fam = cfg.family
    total = cfg.d_model * cfg.vocab_size        # lm head (tied or not)
    if fam == "ssm":
        return total + cfg.n_layers * _ssm_matmul_params(cfg)
    if fam == "hybrid":
        sites = cfg.n_layers // cfg.hybrid.attn_every
        return (total + cfg.n_layers * _ssm_matmul_params(cfg)
                + sites * (_attn_matmul_params(cfg)
                           + 3 * cfg.d_model * cfg.d_ff))
    attn = cfg.n_layers * _attn_matmul_params(cfg)
    if fam == "moe":
        fk = cfg.moe.first_k_dense
        ffn = (cfg.n_layers - fk) * _ffn_matmul_params(cfg, active=True) \
            + fk * 3 * cfg.d_model * cfg.d_ff
    else:
        ffn = cfg.n_layers * 3 * cfg.d_model * cfg.d_ff
    if cfg.frontend.kind != "none":
        total += cfg.frontend.embed_dim * cfg.d_model
    return total + attn + ffn


# ---------------------------------------------------------------------------
# step costs
# ---------------------------------------------------------------------------

def step_costs(cfg: ArchConfig, shape: InputShape) -> StepCosts:
    B, S = float(shape.global_batch), float(shape.seq_len)
    window = tasks.effective_window(cfg, shape)
    N = float(cfg.n_params())
    N_active = float(cfg.n_active_params())
    p_bytes = 2.0 * N                       # bf16 params

    if shape.kind in ("train", "prefill"):
        tokens = B * S
        mm = 2.0 * matmul_params_active(cfg) * tokens
        att = {"full": 0.0, "kernel": 0.0}
        n_attn = (cfg.n_layers // cfg.hybrid.attn_every
                  if cfg.family == "hybrid" else
                  (cfg.n_layers if cfg.family != "ssm" else 0))
        if n_attn:
            per = _attn_seq_flops(cfg, B, S, S, window=window, causal=True)
            att = {k: n_attn * v for k, v in per.items()}
        ssd = 0.0
        if cfg.family in ("ssm", "hybrid"):
            ssd = cfg.n_layers * _ssd_seq_flops(cfg, tokens)
        fwd_full = mm + att["full"] + ssd
        fwd_kern = mm + att["kernel"] + ssd
        model_flops = 6.0 * N_active * tokens

        if shape.kind == "train":
            flops = 4.0 * fwd_full          # fwd + bwd(2×) + remat(1×)
            flops_k = 4.0 * fwd_kern
            # params ×3 passes + grads 2 + opt (read µν, write µν+p) f32
            hbm = (3.0 * p_bytes + 2.0 * p_bytes + 5.0 * 4.0 * N
                   + 6.0 * cfg.n_layers * tokens * cfg.d_model * 2.0)
            note = "train: 4x fwd (remat block); opt f32 moments"
        else:
            flops = fwd_full
            flops_k = fwd_kern
            model_flops = 2.0 * N_active * tokens   # inference MFU basis
            hbm = (p_bytes
                   + 2.0 * cfg.n_layers * tokens * cfg.d_model * 2.0)
            note = "prefill: 1x fwd + cache write"
        return StepCosts(flops, flops_k, model_flops, hbm, note)

    # ---- decode: one token per sequence against a cache -------------------
    T = float(tasks.effective_cache_len(cfg, shape))
    tokens = B
    mm = 2.0 * matmul_params_active(cfg) * tokens
    att = ssd = 0.0
    cache_bytes = 0.0
    if cfg.family in ("ssm", "hybrid"):
        from repro.models import ssm as ssm_lib
        m = ssm_lib.dims(cfg)
        ssd = cfg.n_layers * 4.0 * B * m["d_in"] * m["N"]
        cache_bytes += cfg.n_layers * B * (m["H"] * m["P"] * m["N"]) * 4.0 * 2
    n_attn = (cfg.n_layers // cfg.hybrid.attn_every
              if cfg.family == "hybrid" else
              (cfg.n_layers if cfg.family != "ssm" else 0))
    if n_attn:
        if cfg.mla:
            att = n_attn * _mla_decode_attn_flops(cfg, B, T)
            per_tok_cache = (cfg.mla.kv_lora_rank
                             + cfg.mla.qk_rope_head_dim) * 2.0
        else:
            hd = cfg.resolved_head_dim
            att = n_attn * 2.0 * B * T * cfg.n_heads * 2 * hd
            per_tok_cache = 2.0 * cfg.n_kv_heads * hd * 2.0
        cache_bytes += n_attn * B * T * per_tok_cache
    flops = mm + att + ssd
    model_flops = 2.0 * N_active * tokens
    hbm = p_bytes + cache_bytes
    return StepCosts(flops, flops, model_flops, hbm,
                     f"decode: cache_len={int(T)} (window={window})")
