"""Production mesh construction.

Target hardware: TPU v5e pods — 256 chips/pod, (data=16, model=16) per pod;
the multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips).  Defined
as a FUNCTION so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
