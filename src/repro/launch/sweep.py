"""Sweep driver over the Experiment front door.

Default (``train``) mode: expand a config grid of dotted overrides and run
every combination through ``repro.launch.train`` in a fresh subprocess
(clean XLA state per run), resumable — combos with an existing artifact
JSON are skipped.

  PYTHONPATH=src python -m repro.launch.sweep --reduced --steps 4 \\
      --grid flow.trainer_type=flow_grpo,awm --grid flow.eta=0.3,0.7

``--mode dryrun`` preserves the historical (arch × shape) dry-run matrix
consumed by benchmarks/report.py.
"""
from __future__ import annotations

import argparse
import itertools
import json
import os
import subprocess
import sys
import time

from repro import configs
from repro.config import INPUT_SHAPES

OUT_DIR = "experiments/dryrun"
TRAIN_OUT_DIR = "experiments/sweep"


# ---------------------------------------------------------------- train grid

def grid_combos(grid_specs):
    """``["a=1,2", "b=x"]`` -> [{"a":"1","b":"x"}, {"a":"2","b":"x"}]."""
    axes = []
    seen = set()
    for spec in grid_specs:
        path, _, vals = spec.partition("=")
        if not vals:
            raise SystemExit(f"bad --grid {spec!r}: expected PATH=V1,V2,...")
        if path in seen:   # dict(combo) would silently drop the first axis
            raise SystemExit(f"duplicate --grid axis {path!r}: merge the "
                             "values into one PATH=V1,V2,... spec")
        seen.add(path)
        axes.append([(path, v) for v in vals.split(",")])
    return [dict(combo) for combo in itertools.product(*axes)]


def combo_slug(combo) -> str:
    return "__".join(f"{p.replace('.', '_')}={v}" for p, v in
                     sorted(combo.items())) or "base"


def run_train_combo(combo, args) -> dict:
    slug = combo_slug(combo)
    art = os.path.join(TRAIN_OUT_DIR, slug + ".json")
    if os.path.exists(art):
        return {"skipped": True}
    cmd = [sys.executable, "-m", "repro.launch.train"]
    if args.steps is not None:           # None: respect the config's steps
        cmd += ["--steps", str(args.steps)]
    if args.config:
        cmd += ["--config", args.config]
    if args.reduced:
        cmd.append("--reduced")
    for path, val in combo.items():
        cmd += ["--set", f"{path}={val}"]
    cmd += ["--set", f"loop.log_file={art}",
            "--set", f"loop.ckpt_dir={os.path.join(TRAIN_OUT_DIR, slug)}"]
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)           # clean XLA state per run
    t0 = time.time()
    r = subprocess.run(cmd, capture_output=True, text=True,
                       timeout=args.timeout, env=env, cwd=os.getcwd())
    ok = r.returncode == 0 and os.path.exists(art)
    return {"ok": ok, "wall_s": round(time.time() - t0, 1),
            "stderr_tail": r.stderr[-2000:] if not ok else ""}


# ------------------------------------------------------------- dryrun matrix

def artifact_path(arch: str, shape: str, multi_pod: bool,
                  variant: str = "baseline") -> str:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{variant}" if variant != "baseline" else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def run_pair(arch: str, shape: str, multi_pod: bool, *, timeout: int = 3600,
             variant: str = "baseline", extra_env=None) -> dict:
    path = artifact_path(arch, shape, multi_pod, variant)
    if os.path.exists(path):
        with open(path) as f:
            return {"skipped": True, **json.load(f)}
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--variant", variant]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    if extra_env:
        env.update(extra_env)
    t0 = time.time()
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=os.getcwd())
    ok = r.returncode == 0 and os.path.exists(path)
    return {"ok": ok, "wall_s": round(time.time() - t0, 1),
            "stderr_tail": r.stderr[-2000:] if not ok else ""}


def _report(results) -> None:
    n_fail = sum(1 for _, r in results if not (r.get("ok") or
                                               r.get("skipped")))
    print(f"\nsweep done: {len(results)} runs, {n_fail} failures")
    sys.exit(1 if n_fail else 0)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="train", choices=["train", "dryrun"])
    # train-grid mode
    ap.add_argument("--grid", action="append", default=[],
                    metavar="DOTTED.PATH=V1,V2",
                    help="sweep axis of --set overrides (repeatable)")
    ap.add_argument("--config", default="", help="base RunConfig JSON")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=None,
                    help="override steps per combo (default: the config's)")
    # dryrun mode
    ap.add_argument("--archs", default=",".join(configs.ARCH_IDS))
    ap.add_argument("--shapes", default=",".join(INPUT_SHAPES))
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--timeout", type=int, default=5400)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--env", default="",
                    help="comma-separated KEY=VAL extra env for dryrun")
    args = ap.parse_args()

    results = []
    if args.mode == "train":
        os.makedirs(TRAIN_OUT_DIR, exist_ok=True)
        for combo in grid_combos(args.grid):
            tag = combo_slug(combo)
            try:
                r = run_train_combo(combo, args)
            except subprocess.TimeoutExpired:
                r = {"ok": False, "stderr_tail": "TIMEOUT"}
            status = ("skip" if r.get("skipped")
                      else "ok" if r.get("ok") else "FAIL")
            print(f"[{status}] {tag}"
                  + (f"  ({r['wall_s']}s)" if "wall_s" in r else "")
                  + ("\n" + r.get("stderr_tail", "")
                     if status == "FAIL" else ""), flush=True)
            results.append((tag, r))
        _report(results)

    extra_env = dict(kv.split("=", 1) for kv in args.env.split(",") if kv)
    for arch in args.archs.split(","):
        for shape in args.shapes.split(","):
            for mesh in args.meshes.split(","):
                multi = mesh == "multi"
                tag = f"{arch} × {shape} × {'2pod' if multi else '1pod'}"
                try:
                    r = run_pair(arch, shape, multi, timeout=args.timeout,
                                 variant=args.variant, extra_env=extra_env)
                except subprocess.TimeoutExpired:
                    r = {"ok": False, "stderr_tail": "TIMEOUT"}
                if r.get("skipped"):
                    print(f"[skip] {tag}", flush=True)
                elif r.get("ok"):
                    print(f"[ok]   {tag}  ({r['wall_s']}s)", flush=True)
                else:
                    print(f"[FAIL] {tag}\n{r.get('stderr_tail', '')}",
                          flush=True)
                results.append((tag, r))
    _report(results)


if __name__ == "__main__":
    main()
