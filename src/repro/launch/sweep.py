"""Dry-run sweep driver: every (arch × shape) × {single-pod, multi-pod} in a
fresh subprocess (clean XLA_FLAGS / device-count state per run), resumable —
existing artifact JSONs are skipped.

  PYTHONPATH=src python -m repro.launch.sweep [--multi-pod-only] [--archs a,b]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro import configs
from repro.config import INPUT_SHAPES

OUT_DIR = "experiments/dryrun"


def artifact_path(arch: str, shape: str, multi_pod: bool,
                  variant: str = "baseline") -> str:
    mesh = "pod2x16x16" if multi_pod else "pod16x16"
    suffix = f"__{variant}" if variant != "baseline" else ""
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{mesh}{suffix}.json")


def run_pair(arch: str, shape: str, multi_pod: bool, *, timeout: int = 3600,
             variant: str = "baseline", extra_env=None) -> dict:
    path = artifact_path(arch, shape, multi_pod, variant)
    if os.path.exists(path):
        with open(path) as f:
            return {"skipped": True, **json.load(f)}
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--variant", variant]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    if extra_env:
        env.update(extra_env)
    t0 = time.time()
    r = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                       env=env, cwd=os.getcwd())
    ok = r.returncode == 0 and os.path.exists(path)
    return {"ok": ok, "wall_s": round(time.time() - t0, 1),
            "stderr_tail": r.stderr[-2000:] if not ok else ""}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=",".join(configs.ARCH_IDS))
    ap.add_argument("--shapes", default=",".join(INPUT_SHAPES))
    ap.add_argument("--meshes", default="single,multi")
    ap.add_argument("--timeout", type=int, default=5400)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--env", default="",
                    help="comma-separated KEY=VAL extra env for dryrun")
    args = ap.parse_args()

    extra_env = dict(kv.split("=", 1) for kv in args.env.split(",") if kv)
    archs = args.archs.split(",")
    shapes = args.shapes.split(",")
    meshes = args.meshes.split(",")

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh in meshes:
                multi = mesh == "multi"
                tag = f"{arch} × {shape} × {'2pod' if multi else '1pod'}"
                try:
                    r = run_pair(arch, shape, multi, timeout=args.timeout,
                                 variant=args.variant, extra_env=extra_env)
                except subprocess.TimeoutExpired:
                    r = {"ok": False, "stderr_tail": "TIMEOUT"}
                if r.get("skipped"):
                    print(f"[skip] {tag}", flush=True)
                elif r.get("ok"):
                    print(f"[ok]   {tag}  ({r['wall_s']}s)", flush=True)
                else:
                    print(f"[FAIL] {tag}\n{r.get('stderr_tail', '')}",
                          flush=True)
                results.append((tag, r))
    n_fail = sum(1 for _, r in results if not (r.get("ok") or
                                               r.get("skipped")))
    print(f"\nsweep done: {len(results)} pairs, {n_fail} failures")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
