"""Trajectory sampling for RL fine-tuning (the paper's sampling phase).

Produces grouped trajectories (G samples per prompt — the GRPO group) with
per-step transition log-probabilities, via ``lax.scan`` over denoising steps.
Supports full-SDE (Flow-GRPO), mixed ODE/SDE (MixGRPO — only a window of
timesteps is stochastic) and pure-ODE (NFT/AWM) rollouts through the same
code path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.core.schedulers import SDESchedulerMixin
from repro.models.flow import FlowAdapter

F32 = jnp.float32


class Trajectory(NamedTuple):
    xs: jax.Array        # (T+1, B, Lt, ld)  states (xs[0] = noise)
    logps: jax.Array     # (T, B)            transition log-probs (0 on ODE steps)
    ts: jax.Array        # (T+1,)            descending time grid
    sde_mask: jax.Array  # (T,) bool         which steps were stochastic
    cond: jax.Array      # (B, Lc, cond_dim) condition embeddings

    @property
    def x0(self) -> jax.Array:
        return self.xs[-1]


SDE_MODES = ("mixed", "all_sde", "all_ode")


def checkpoint_scan_body(body, remat: str, policy=None):
    """Wrap a ``lax.scan`` body in ``jax.checkpoint`` under the
    ``PerfConfig.remat`` policy — the one place the policy maps onto the
    primitive (the rollout below and the GRPO loss scan both use it).
    Applies for both "scan" and "block": block remat checkpoints layers
    *inside* the body too, but without the outer scan checkpoint the scan
    backward would still save every body's residuals, defeating it.

    ``policy`` is an optional ``jax.checkpoint`` saveable-residual policy
    (``perf.remat_offload`` passes the host-offload policy built in
    ``repro.perf`` — core cannot import that package, so the resolved
    policy object is threaded in); residuals it names must be tagged with
    ``checkpoint_name`` inside ``body``."""
    if remat == "none":
        return body
    if policy is not None:
        return jax.checkpoint(body, policy=policy)
    return jax.checkpoint(body)


def name_residual(x: jax.Array, policy, name: str = "velocity"
                  ) -> jax.Array:
    """Tag ``x`` as a named checkpoint residual when an offload ``policy``
    is active (identity otherwise — plain remat stays byte-for-byte the
    program it always was)."""
    if policy is None:
        return x
    return checkpoint_name(x, name)


def rollout(adapter: FlowAdapter, params, cond: jax.Array, key: jax.Array,
            scheduler: SDESchedulerMixin, num_steps: int,
            sde_mask: Optional[jax.Array] = None, *,
            sde_mode: str = "mixed", remat: str = "none",
            remat_policy=None) -> Trajectory:
    """cond: (B, Lc, cond_dim) — already group-repeated by the caller.

    ``sde_mode`` statically specializes the scan body when the caller
    *knows* the mask (perf dead-branch elimination, ``repro.perf``):
    ``"mixed"`` is the general path — every step computes both the SDE and
    ODE update and selects by ``sde_mask``; ``"all_sde"`` drops the dead
    ODE branch (Flow-GRPO/Guard, whose mask is statically all-ones);
    ``"all_ode"`` drops the SDE branch, the per-step noise draws AND the
    dead log-density (NFT/AWM — their logps are identically zero).  Both
    specializations produce exactly the values the mixed path selects.

    ``remat`` ("none" | "scan" | "block", ``PerfConfig.remat``) wraps the
    scan body in ``jax.checkpoint``; "block" additionally threads the
    backbone's per-layer remat through ``adapter.velocity``.
    ``remat_policy`` (``perf.remat_offload``) names the per-step velocity
    as a host-offloadable residual instead of recomputing it."""
    if sde_mode not in SDE_MODES:
        raise ValueError(f"sde_mode must be one of {SDE_MODES}, "
                         f"got {sde_mode!r}")
    B = cond.shape[0]
    ts = scheduler.timesteps(num_steps)
    if sde_mask is None:
        sde_mask = jnp.ones((num_steps,), bool)
    block = remat == "block"

    k_init, k_steps = jax.random.split(key)
    x_init = adapter.init_latent(k_init, B)
    # hoisted out of the body: the (T, B) per-step timestep batch is scan
    # input instead of a per-iteration broadcast materialized in the body
    tbs = jnp.broadcast_to(ts[:-1, None], (num_steps, B)).astype(F32)

    if sde_mode == "all_ode":
        def body(x, inp):
            t, t_next, tb = inp
            v = name_residual(
                adapter.velocity(params, x, tb, cond, remat=block),
                remat_policy)
            x_next = scheduler.step_ode(v, x, t, t_next)
            return x_next, (x_next, jnp.zeros((B,), F32))
        xs_in = (ts[:-1], ts[1:], tbs)
    elif sde_mode == "all_sde":
        def body(x, inp):
            t, t_next, tb, k = inp
            v = name_residual(
                adapter.velocity(params, x, tb, cond, remat=block),
                remat_policy)
            x_next, logp = scheduler.step(v, x, t, t_next, k)
            return x_next, (x_next, logp)
        xs_in = (ts[:-1], ts[1:], tbs, jax.random.split(k_steps, num_steps))
    else:
        def body(x, inp):
            t, t_next, tb, is_sde, k = inp
            v = name_residual(
                adapter.velocity(params, x, tb, cond, remat=block),
                remat_policy)
            x_sde, logp = scheduler.step(v, x, t, t_next, k)
            x_ode = scheduler.step_ode(v, x, t, t_next)
            x_next = jnp.where(is_sde, x_sde, x_ode)
            logp = jnp.where(is_sde, logp, jnp.zeros_like(logp))
            return x_next, (x_next, logp)
        xs_in = (ts[:-1], ts[1:], tbs, sde_mask,
                 jax.random.split(k_steps, num_steps))

    body = checkpoint_scan_body(body, remat, policy=remat_policy)
    _, (xs_tail, logps) = jax.lax.scan(body, x_init, xs_in)
    xs = jnp.concatenate([x_init[None], xs_tail], axis=0)
    return Trajectory(xs=xs, logps=logps, ts=ts, sde_mask=sde_mask, cond=cond)


def request_keys(key: jax.Array, batch: int) -> jax.Array:
    """(batch, 2) per-request PRNG keys: row i = fold_in(key, i).  The unit
    of determinism for keyed rollouts — request i's latent depends on row i
    alone, never on who else shares the batch."""
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(batch))


def rollout_keyed(adapter: FlowAdapter, params, cond: jax.Array,
                  keys: jax.Array, scheduler: SDESchedulerMixin,
                  num_steps: int,
                  sde_mask: Optional[jax.Array] = None) -> Trajectory:
    """Per-request-keyed rollout: ``keys`` is (B, 2) — one PRNG key per
    sample, driving both its init latent and its per-step noise.

    Unlike :func:`rollout` (one batch key: noise depends on batch
    composition), each sample's trajectory here is a pure function of its
    own (cond row, key row) — bit-identical whether it runs alone, padded,
    in any bucket size, or sharded over devices.  This is the invariant the
    serving engine's bucketed batching and sharded inference rest on."""
    B = cond.shape[0]
    if keys.shape[0] != B:
        raise ValueError(
            f"rollout_keyed: {B} cond rows but {keys.shape[0]} keys — "
            "every request needs exactly one PRNG key")
    ts = scheduler.timesteps(num_steps)
    if sde_mask is None:
        sde_mask = jnp.ones((num_steps,), bool)

    shape = (adapter.flow_cfg.latent_tokens, adapter.flow_cfg.latent_dim)
    k2 = jax.vmap(jax.random.split)(keys)
    k_init, k_step = k2[:, 0], k2[:, 1]
    # per-key init through the adapter's hook (custom priors apply to the
    # serving path too); bit-equal to a direct (Lt, ld) draw for the
    # default Gaussian since the element count per key is identical
    x_init = jax.vmap(lambda k: adapter.init_latent(k, 1)[0])(k_init)

    # hoisted out of the body (scan input, not per-iteration broadcast)
    tbs = jnp.broadcast_to(ts[:-1, None], (num_steps, B)).astype(F32)

    def body(x, inp):
        t, t_next, tb, is_sde, i = inp
        v = adapter.velocity(params, x, tb, cond).astype(F32)
        xf = x.astype(F32)
        eps = jax.vmap(lambda k: jax.random.normal(
            jax.random.fold_in(k, i), shape, F32))(k_step)
        # step_with_eps so fused kernels (flow_sde's Pallas sde_step)
        # dispatch here exactly as they do in `rollout`; masked
        # (is_sde=False) steps integrate the plain flow (step_ode), NOT
        # the SDE drift mean — for eta>0 schedulers the drift carries a
        # nonzero sigma^2 correction even with the noise masked off
        # (the MixGRPO ODE window)
        x_sde, logp_sde = scheduler.step_with_eps(v, xf, t, t_next, eps)
        x_ode = scheduler.step_ode(v, xf, t, t_next)
        x_next = jnp.where(is_sde, x_sde, x_ode)
        logp = jnp.where(is_sde, logp_sde, jnp.zeros((B,), F32))
        return x_next, (x_next, logp)

    _, (xs_tail, logps) = jax.lax.scan(
        body, x_init, (ts[:-1], ts[1:], tbs, sde_mask,
                       jnp.arange(num_steps)))
    xs = jnp.concatenate([x_init[None], xs_tail], axis=0)
    return Trajectory(xs=xs, logps=logps, ts=ts, sde_mask=sde_mask, cond=cond)


def group_repeat(cond: jax.Array, group_size: int) -> jax.Array:
    """(P, Lc, D) prompts -> (P·G, Lc, D) with each prompt repeated G times
    (consecutive — group g of prompt p occupies rows p·G..p·G+G−1)."""
    if group_size < 1:
        raise ValueError(f"group_size must be >= 1, got {group_size}")
    return jnp.repeat(cond, group_size, axis=0)


def mix_sde_mask(num_steps: int, window: int, shift: int = 0) -> jnp.ndarray:
    """MixGRPO: SDE on a sliding window of timesteps, ODE elsewhere."""
    idx = (jnp.arange(num_steps) - shift) % num_steps
    return idx < window
