"""Preprocessing-based memory optimization (paper §2.2).

Two-phase training:

1. **Preprocess** — run every prompt through the frozen condition encoders
   once, writing (prompt embeddings, pooled embeddings) to a zstd-compressed
   on-disk cache keyed by prompt hash.
2. **Train** — the training process reads embeddings from the cache and
   *never instantiates* the frozen encoders: "transformer-only on GPU".

``FrozenTextEncoder`` stands in for the paper's T5/CLIP towers (DESIGN.md
§8): a deterministic hash-seeded token embedding + projection with a real
(configurable, default ~67M-param) weight matrix, so the offload saving and
the redundant-encoding cost it eliminates are both measurable.
"""
from __future__ import annotations

import hashlib
import io
import os
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:
    import zstandard
except ImportError:                      # pragma: no cover - env dependent
    zstandard = None                     # gate: fall back to raw npz blobs

F32 = jnp.float32

# zstd frame magic — lets ``PreprocessCache.get`` auto-detect whether a blob
# was written compressed, so caches stay readable across environments
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def prompt_key(prompt: str) -> str:
    return hashlib.sha1(prompt.encode()).hexdigest()[:24]


class FrozenTextEncoder:
    """Frozen condition encoder (text-tower stand-in).

    Tokenizes by word hashing, embeds via a frozen table, and runs a frozen
    projection — deterministic in the prompt.  ``n_params`` makes the memory
    cost of *not* offloading it visible to the efficiency benchmark.
    """

    def __init__(self, cond_dim: int = 512, cond_len: int = 16,
                 vocab: int = 32768, hidden: int = 2048, depth: int = 2,
                 seed: int = 3):
        self.cond_dim, self.cond_len = cond_dim, cond_len
        self.vocab, self.hidden, self.depth = vocab, hidden, depth
        keys = jax.random.split(jax.random.PRNGKey(seed), depth + 2)
        # frozen weights — this is what preprocessing lets us offload
        self.embed = jax.random.normal(keys[0], (vocab, hidden), F32) * 0.02
        self.layers = [jax.random.normal(k, (hidden, hidden), F32)
                       / np.sqrt(hidden) for k in keys[1:-1]]
        self.w_out = jax.random.normal(keys[-1], (hidden, cond_dim), F32) \
            / np.sqrt(hidden)
        self._encode_jit = jax.jit(self._encode)

    @property
    def n_params(self) -> int:
        return int(self.embed.size + sum(w.size for w in self.layers)
                   + self.w_out.size)

    def tokenize(self, prompt: str) -> np.ndarray:
        words = (prompt.lower().split() + ["<pad>"] * self.cond_len)
        ids = [int(hashlib.sha1(w.encode()).hexdigest()[:8], 16) % self.vocab
               for w in words[:self.cond_len]]
        return np.asarray(ids, np.int32)

    def _encode(self, ids: jax.Array) -> Dict[str, jax.Array]:
        h = jnp.take(self.embed, ids, axis=0)            # (B, L, hidden)
        for w in self.layers:
            h = jnp.tanh(h @ w)
        emb = h @ self.w_out                              # (B, L, cond_dim)
        return {"cond": emb, "pooled": emb.mean(axis=1)}

    def encode(self, prompts: Sequence[str]) -> Dict[str, jax.Array]:
        ids = jnp.stack([jnp.asarray(self.tokenize(p)) for p in prompts])
        return self._encode_jit(ids)


class PreprocessCache:
    """zstd-compressed npz cache of condition embeddings.

    When the ``zstandard`` module is unavailable, blobs are written as raw
    npz; reads auto-detect the frame type, so mixed caches stay valid."""

    def __init__(self, cache_dir: str):
        self.dir = cache_dir
        os.makedirs(cache_dir, exist_ok=True)
        self._cctx = zstandard.ZstdCompressor(level=3) if zstandard else None
        self._dctx = zstandard.ZstdDecompressor() if zstandard else None

    def _path(self, prompt: str) -> str:
        return os.path.join(self.dir, prompt_key(prompt) + ".npz.zst")

    def has(self, prompt: str) -> bool:
        return os.path.exists(self._path(prompt))

    def put(self, prompt: str, arrays: Dict[str, np.ndarray]) -> None:
        buf = io.BytesIO()
        np.savez(buf, **{k: np.asarray(v) for k, v in arrays.items()})
        payload = buf.getvalue()
        if self._cctx is not None:
            payload = self._cctx.compress(payload)
        with open(self._path(prompt), "wb") as f:
            f.write(payload)

    def get(self, prompt: str) -> Dict[str, np.ndarray]:
        with open(self._path(prompt), "rb") as f:
            raw = f.read()
        if raw[:4] == _ZSTD_MAGIC:
            if self._dctx is None:
                raise RuntimeError(
                    "cache entry is zstd-compressed but the 'zstandard' "
                    "module is not installed; re-run preprocessing or "
                    "install zstandard")
            raw = self._dctx.decompress(raw)
        with np.load(io.BytesIO(raw)) as z:
            return {k: z[k] for k in z.files}


def preprocess_dataset(prompts: Sequence[str], cache: PreprocessCache,
                       encoder: Optional[FrozenTextEncoder] = None,
                       batch: int = 64, **enc_kw) -> int:
    """Phase 1: encode + cache every prompt. Returns #newly cached."""
    todo = [p for p in prompts if not cache.has(p)]
    if todo and encoder is None:
        encoder = FrozenTextEncoder(**enc_kw)
    n = 0
    for i in range(0, len(todo), batch):
        chunk = todo[i:i + batch]
        out = encoder.encode(chunk)
        cond = np.asarray(out["cond"])
        pooled = np.asarray(out["pooled"])
        for j, p in enumerate(chunk):
            cache.put(p, {"cond": cond[j], "pooled": pooled[j]})
            n += 1
    return n


class ConditionProvider:
    """Training-phase condition source.

    ``preprocessing=True``  -> reads the cache; the encoder is NEVER
                               instantiated (``encoder_resident`` stays
                               False — the paper's offload guarantee).
                               A cache miss raises :class:`KeyError` naming
                               the missing prompt, unless
                               ``encode_on_miss=True`` opts into lazily
                               encoding (and caching) it — which instantiates
                               the frozen tower and forfeits the offload.
    ``preprocessing=False`` -> re-encodes every request (the baseline the
                               paper's Table 2 compares against).

    Prefetch: :meth:`prefetch` warms the condition batch for a *future*
    ``get`` on a single background worker — the TrainLoop arms it for the
    next step's prompts right after dispatching the current step, so cache
    IO / np stacking / live encoding overlap the in-flight device work
    instead of sitting on the critical path.  ``get`` consumes a matching
    pending prefetch (same prompt tuple) or computes synchronously; all
    cache/encode work runs on the one worker either way, so the encoder
    and cache are never touched from two threads at once.
    """

    def __init__(self, *, preprocessing: bool, cache: Optional[PreprocessCache]
                 = None, encoder_kw: Optional[dict] = None,
                 encode_on_miss: bool = False):
        self.preprocessing = preprocessing
        self.cache = cache
        self.encode_on_miss = encode_on_miss
        self._encoder: Optional[FrozenTextEncoder] = None
        self._encoder_kw = encoder_kw or {}
        self._executor: Optional[ThreadPoolExecutor] = None
        self._pending: Optional[Tuple[Tuple[str, ...], Future]] = None

    @property
    def encoder_resident(self) -> bool:
        return self._encoder is not None

    @property
    def resident_param_bytes(self) -> int:
        return (self._encoder.n_params * 4) if self._encoder else 0

    def _ensure_encoder(self) -> FrozenTextEncoder:
        if self._encoder is None:              # frozen tower stays resident
            self._encoder = FrozenTextEncoder(**self._encoder_kw)
        return self._encoder

    def _cached(self, prompt: str) -> Dict[str, np.ndarray]:
        try:
            return self.cache.get(prompt)
        except FileNotFoundError:
            if not self.encode_on_miss:
                raise KeyError(
                    f"prompt not in preprocessing cache "
                    f"({self.cache.dir!r}): {prompt!r} — run "
                    "preprocess_dataset() over the corpus first, or opt in "
                    "with ConditionProvider(..., encode_on_miss=True)"
                ) from None
            out = self._ensure_encoder().encode([prompt])
            rec = {"cond": np.asarray(out["cond"])[0],
                   "pooled": np.asarray(out["pooled"])[0]}
            self.cache.put(prompt, rec)
            return rec

    def _get_now(self, prompts: Sequence[str]) -> Dict[str, jax.Array]:
        if self.preprocessing:
            assert self.cache is not None, "preprocessing requires a cache"
            arrs = [self._cached(p) for p in prompts]
            return {
                "cond": jnp.stack([jnp.asarray(a["cond"]) for a in arrs]),
                "pooled": jnp.stack([jnp.asarray(a["pooled"]) for a in arrs]),
            }
        return self._ensure_encoder().encode(prompts)

    def prefetch(self, prompts: Sequence[str]) -> None:
        """Warm ``get(prompts)`` on the background worker (one batch ahead
        — a newer prefetch supersedes an unconsumed older one).  Errors
        (e.g. a cache miss) surface at the consuming ``get``."""
        key = tuple(prompts)
        if self._pending is not None and self._pending[0] == key:
            return
        if self._executor is None:
            self._executor = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="cond-prefetch")
        self._pending = (key, self._executor.submit(self._get_now,
                                                    list(prompts)))

    def get(self, prompts: Sequence[str]) -> Dict[str, jax.Array]:
        pending, self._pending = self._pending, None
        if pending is not None and pending[0] == tuple(prompts):
            return pending[1].result()
        if self._executor is not None:
            # a mismatched prefetch may still be running: route this batch
            # through the same single worker so the encoder/cache are never
            # driven from two threads concurrently
            return self._executor.submit(self._get_now,
                                         list(prompts)).result()
        return self._get_now(prompts)
