"""Preprocessing-based memory optimization (paper §2.2).

Two-phase training:

1. **Preprocess** — run every prompt through the frozen condition encoders
   once, writing (prompt embeddings, pooled embeddings) to a zstd-compressed
   on-disk cache keyed by prompt hash.
2. **Train** — the training process reads embeddings from the cache and
   *never instantiates* the frozen encoders: "transformer-only on GPU".

``FrozenTextEncoder`` stands in for the paper's T5/CLIP towers (DESIGN.md
§8): a deterministic hash-seeded token embedding + projection with a real
(configurable, default ~67M-param) weight matrix, so the offload saving and
the redundant-encoding cost it eliminates are both measurable.
"""
from __future__ import annotations

import hashlib
import io
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
import zstandard

F32 = jnp.float32


def prompt_key(prompt: str) -> str:
    return hashlib.sha1(prompt.encode()).hexdigest()[:24]


class FrozenTextEncoder:
    """Frozen condition encoder (text-tower stand-in).

    Tokenizes by word hashing, embeds via a frozen table, and runs a frozen
    projection — deterministic in the prompt.  ``n_params`` makes the memory
    cost of *not* offloading it visible to the efficiency benchmark.
    """

    def __init__(self, cond_dim: int = 512, cond_len: int = 16,
                 vocab: int = 32768, hidden: int = 2048, depth: int = 2,
                 seed: int = 3):
        self.cond_dim, self.cond_len = cond_dim, cond_len
        self.vocab, self.hidden, self.depth = vocab, hidden, depth
        keys = jax.random.split(jax.random.PRNGKey(seed), depth + 2)
        # frozen weights — this is what preprocessing lets us offload
        self.embed = jax.random.normal(keys[0], (vocab, hidden), F32) * 0.02
        self.layers = [jax.random.normal(k, (hidden, hidden), F32)
                       / np.sqrt(hidden) for k in keys[1:-1]]
        self.w_out = jax.random.normal(keys[-1], (hidden, cond_dim), F32) \
            / np.sqrt(hidden)
        self._encode_jit = jax.jit(self._encode)

    @property
    def n_params(self) -> int:
        return int(self.embed.size + sum(w.size for w in self.layers)
                   + self.w_out.size)

    def tokenize(self, prompt: str) -> np.ndarray:
        words = (prompt.lower().split() + ["<pad>"] * self.cond_len)
        ids = [int(hashlib.sha1(w.encode()).hexdigest()[:8], 16) % self.vocab
               for w in words[:self.cond_len]]
        return np.asarray(ids, np.int32)

    def _encode(self, ids: jax.Array) -> Dict[str, jax.Array]:
        h = jnp.take(self.embed, ids, axis=0)            # (B, L, hidden)
        for w in self.layers:
            h = jnp.tanh(h @ w)
        emb = h @ self.w_out                              # (B, L, cond_dim)
        return {"cond": emb, "pooled": emb.mean(axis=1)}

    def encode(self, prompts: Sequence[str]) -> Dict[str, jax.Array]:
        ids = jnp.stack([jnp.asarray(self.tokenize(p)) for p in prompts])
        return self._encode_jit(ids)


class PreprocessCache:
    """zstd-compressed npz cache of condition embeddings."""

    def __init__(self, cache_dir: str):
        self.dir = cache_dir
        os.makedirs(cache_dir, exist_ok=True)
        self._cctx = zstandard.ZstdCompressor(level=3)
        self._dctx = zstandard.ZstdDecompressor()

    def _path(self, prompt: str) -> str:
        return os.path.join(self.dir, prompt_key(prompt) + ".npz.zst")

    def has(self, prompt: str) -> bool:
        return os.path.exists(self._path(prompt))

    def put(self, prompt: str, arrays: Dict[str, np.ndarray]) -> None:
        buf = io.BytesIO()
        np.savez(buf, **{k: np.asarray(v) for k, v in arrays.items()})
        with open(self._path(prompt), "wb") as f:
            f.write(self._cctx.compress(buf.getvalue()))

    def get(self, prompt: str) -> Dict[str, np.ndarray]:
        with open(self._path(prompt), "rb") as f:
            raw = self._dctx.decompress(f.read())
        with np.load(io.BytesIO(raw)) as z:
            return {k: z[k] for k in z.files}


def preprocess_dataset(prompts: Sequence[str], cache: PreprocessCache,
                       encoder: Optional[FrozenTextEncoder] = None,
                       batch: int = 64, **enc_kw) -> int:
    """Phase 1: encode + cache every prompt. Returns #newly cached."""
    todo = [p for p in prompts if not cache.has(p)]
    if todo and encoder is None:
        encoder = FrozenTextEncoder(**enc_kw)
    n = 0
    for i in range(0, len(todo), batch):
        chunk = todo[i:i + batch]
        out = encoder.encode(chunk)
        cond = np.asarray(out["cond"])
        pooled = np.asarray(out["pooled"])
        for j, p in enumerate(chunk):
            cache.put(p, {"cond": cond[j], "pooled": pooled[j]})
            n += 1
    return n


class ConditionProvider:
    """Training-phase condition source.

    ``preprocessing=True``  -> reads the cache; the encoder is NEVER
                               instantiated (``encoder_resident`` stays
                               False — the paper's offload guarantee).
    ``preprocessing=False`` -> re-encodes every request (the baseline the
                               paper's Table 2 compares against).
    """

    def __init__(self, *, preprocessing: bool, cache: Optional[PreprocessCache]
                 = None, encoder_kw: Optional[dict] = None):
        self.preprocessing = preprocessing
        self.cache = cache
        self._encoder: Optional[FrozenTextEncoder] = None
        self._encoder_kw = encoder_kw or {}

    @property
    def encoder_resident(self) -> bool:
        return self._encoder is not None

    @property
    def resident_param_bytes(self) -> int:
        return (self._encoder.n_params * 4) if self._encoder else 0

    def get(self, prompts: Sequence[str]) -> Dict[str, jax.Array]:
        if self.preprocessing:
            assert self.cache is not None, "preprocessing requires a cache"
            arrs = [self.cache.get(p) for p in prompts]
            return {
                "cond": jnp.stack([jnp.asarray(a["cond"]) for a in arrs]),
                "pooled": jnp.stack([jnp.asarray(a["pooled"]) for a in arrs]),
            }
        if self._encoder is None:              # frozen tower stays resident
            self._encoder = FrozenTextEncoder(**self._encoder_kw)
        return self._encoder.encode(prompts)
