"""MultiRewardLoader — multi-reward training with automatic deduplication
(paper §2.3 mechanism 2).

Multiple :class:`RewardSpec` entries may reference the same frozen backbone
(``model_id``); the loader instantiates each unique backbone exactly once and
shares its parameters across every reward that references it.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax

from repro import registry
from repro.config import RewardSpec
from repro.core.rewards.base import BaseRewardModel


class MultiRewardLoader:
    def __init__(self, specs: Sequence[RewardSpec], key: jax.Array):
        self.specs = tuple(specs)
        self.models: List[BaseRewardModel] = []
        self.weights: List[float] = []
        self._param_store: Dict[str, object] = {}
        self.unique_loads = 0

        for i, spec in enumerate(self.specs):
            kwargs = dict(spec.args)
            if spec.model_id:
                kwargs["model_id"] = spec.model_id
            model: BaseRewardModel = registry.build(
                "reward", spec.reward_type, **kwargs)
            if model.model_id not in self._param_store:
                self._param_store[model.model_id] = model.load_params(
                    jax.random.fold_in(key, i))
                self.unique_loads += 1
            model.set_params(self._param_store[model.model_id])
            self.models.append(model)
            self.weights.append(spec.weight)

    def __len__(self) -> int:
        return len(self.models)

    def compute_all(self, x0: jax.Array, cond_meta: Dict, *,
                    group_size: int) -> Dict[str, jax.Array]:
        """Returns {reward_name: (B,) raw rewards} for every configured
        reward (groupwise models are evaluated within GRPO groups)."""
        out = {}
        for i, (spec, model) in enumerate(zip(self.specs, self.models)):
            name = f"{spec.reward_type}:{i}"
            if model.kind == "groupwise":
                out[name] = model.score(x0, cond_meta, group_size=group_size)
            else:
                out[name] = model.score(x0, cond_meta)
        return out

    def weight_map(self) -> Dict[str, float]:
        return {f"{s.reward_type}:{i}": s.weight
                for i, s in enumerate(self.specs)}
