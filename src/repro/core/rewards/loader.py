"""MultiRewardLoader — multi-reward training with automatic deduplication
(paper §2.3 mechanism 2).

Multiple :class:`RewardSpec` entries may reference the same frozen backbone
(``model_id``); the loader instantiates each unique backbone exactly once and
shares its parameters across every reward that references it.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import jax

from repro import registry
from repro.config import RewardSpec
from repro.core.rewards.base import BaseRewardModel


class MultiRewardLoader:
    def __init__(self, specs: Sequence[RewardSpec], key: jax.Array):
        self.specs = tuple(specs)
        self.models: List[BaseRewardModel] = []
        self.weights: List[float] = []
        self._param_store: Dict[str, object] = {}
        self.unique_loads = 0

        for i, spec in enumerate(self.specs):
            kwargs = dict(spec.args)
            if spec.model_id:
                kwargs["model_id"] = spec.model_id
            model: BaseRewardModel = registry.build(
                "reward", spec.reward_type, **kwargs)
            if model.model_id not in self._param_store:
                self._param_store[model.model_id] = model.load_params(
                    jax.random.fold_in(key, i))
                self.unique_loads += 1
            model.set_params(self._param_store[model.model_id])
            self.models.append(model)
            self.weights.append(spec.weight)

    def __len__(self) -> int:
        return len(self.models)

    def param_store(self) -> Dict[str, object]:
        """The deduplicated {model_id: params} store (one entry per unique
        frozen backbone, shared across the rewards referencing it)."""
        return dict(self._param_store)

    def rebase(self, store: Dict[str, object]) -> None:
        """Replace the param store wholesale (``perf.offload_rewards``
        moves it to host memory at trainer construction) and repoint every
        model at the new copies.  Runs before any trace."""
        if set(store) != set(self._param_store):
            raise ValueError(
                f"rebase store keys {sorted(store)} != loaded model ids "
                f"{sorted(self._param_store)}")
        self._param_store = dict(store)
        self.bind(self._param_store)

    def bind(self, store: Dict[str, object]) -> None:
        """Point every model at params from ``store`` (keyed by model_id).
        ``compute_all`` uses this to evaluate under caller-supplied params
        — inside the rewards jit they are tracers, so the scorers compute
        on the threaded-in arguments instead of captured constants."""
        for model in self.models:
            model.set_params(store[model.model_id])

    def compute_all(self, x0: jax.Array, cond_meta: Dict, *,
                    group_size: int, params: Dict[str, object] = None
                    ) -> Dict[str, jax.Array]:
        """Returns {reward_name: (B,) raw rewards} for every configured
        reward (groupwise models are evaluated within GRPO groups).

        ``params`` optionally overrides the resident param store for this
        evaluation (the ``perf.offload_rewards`` path passes the jit-
        argument tower store); the models are re-bound to the stable store
        afterwards so no trace-time tracer outlives its trace."""
        if params is not None:
            self.bind(params)
        try:
            out = {}
            for i, (spec, model) in enumerate(zip(self.specs, self.models)):
                name = f"{spec.reward_type}:{i}"
                if model.kind == "groupwise":
                    out[name] = model.score(x0, cond_meta,
                                            group_size=group_size)
                else:
                    out[name] = model.score(x0, cond_meta)
            return out
        finally:
            if params is not None:
                # jaxlint: disable=R003 — restore target: rebase() runs
                # once at trainer construction, strictly before any trace
                self.bind(self._param_store)

    def weight_map(self) -> Dict[str, float]:
        return {f"{s.reward_type}:{i}": s.weight
                for i, s in enumerate(self.specs)}
