"""Reward-model interfaces (paper §2.3).

Two unified interfaces:

* :class:`PointwiseRewardModel` — ``score(x) → R`` per sample.
* :class:`GroupwiseRewardModel` — ``rank(x₁..x_k) → R^k`` relative scores
  within a GRPO group (Pref-GRPO-style pairwise preference rewards).

Every model declares ``model_id`` — the identity of the underlying frozen
network.  :class:`~repro.core.rewards.loader.MultiRewardLoader` deduplicates
on it, so N reward configs referencing one backbone load it once.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


class BaseRewardModel:
    """Common base. ``x0`` is the final latent (B, Lt, ld); ``cond_meta``
    carries condition embeddings / prompt hashes from preprocessing."""

    kind: str = "pointwise"

    def __init__(self, model_id: str = ""):
        self.model_id = model_id or type(self).__name__

    def load_params(self, key: jax.Array) -> Any:
        """Instantiate the frozen scorer's parameters (called once per unique
        model_id by the loader)."""
        return None

    def set_params(self, params: Any) -> None:
        self.params = params


class PointwiseRewardModel(BaseRewardModel):
    kind = "pointwise"

    def score(self, x0: jax.Array, cond_meta: Dict[str, jax.Array]
              ) -> jax.Array:
        """x0: (B, Lt, ld) -> rewards (B,)."""
        raise NotImplementedError


class GroupwiseRewardModel(BaseRewardModel):
    kind = "groupwise"

    def rank(self, x0_groups: jax.Array, cond_meta: Dict[str, jax.Array]
             ) -> jax.Array:
        """x0_groups: (P, G, Lt, ld) -> relative scores (P, G)."""
        raise NotImplementedError

    def score(self, x0: jax.Array, cond_meta: Dict[str, jax.Array], *,
              group_size: int) -> jax.Array:
        """Flatten-compatible wrapper: reshapes (P·G, ...) into groups,
        ranks, and flattens back to (P·G,)."""
        B = x0.shape[0]
        P = B // group_size
        groups = x0.reshape((P, group_size) + x0.shape[1:])
        return self.rank(groups, cond_meta).reshape(B)
