from repro.core.rewards.base import (BaseRewardModel, GroupwiseRewardModel,
                                     PointwiseRewardModel)
from repro.core.rewards.loader import MultiRewardLoader
from repro.core.rewards.aggregate import compute_advantages, group_normalize
from repro.core.rewards import models  # noqa: F401  (registers rewards)

__all__ = ["BaseRewardModel", "PointwiseRewardModel", "GroupwiseRewardModel",
           "MultiRewardLoader", "compute_advantages", "group_normalize"]
