"""Advantage aggregation strategies (paper §2.3 mechanism 3).

Given per-reward raw scores {name: (B,)} for grouped samples (B = P·G with
G consecutive samples per prompt), produce per-sample advantages (B,).

* ``weighted_sum`` — combine first, normalize after:
      A = groupnorm(Σᵢ wᵢ·rᵢ)
* ``gdpo`` — GDPO-style (Liu et al., 2026) per-reward decoupled
  normalization: normalize each reward within its group first, then combine:
      A = Σᵢ wᵢ·groupnorm(rᵢ)
  This prevents a high-variance reward from drowning out the others.

New strategies plug in via ``@registry.register("aggregator", name)`` — the
paper's "implementing new aggregation strategies only requires a new
compute_advantages method".
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro import registry

F32 = jnp.float32


def group_normalize(r: jax.Array, group_size: int, eps: float = 1e-6
                    ) -> jax.Array:
    """(B,) -> (B,): subtract group mean, divide by group std (GRPO)."""
    B = r.shape[0]
    if group_size < 1:
        raise ValueError(f"group_size must be >= 1, got {group_size}")
    if B % group_size != 0:
        raise ValueError(
            f"batch size {B} is not divisible by group_size {group_size}: "
            "GRPO group statistics need whole groups — use group_repeat to "
            "build the batch, or fix num_prompts × group_size")
    g = r.astype(F32).reshape(B // group_size, group_size)
    mu = g.mean(axis=1, keepdims=True)
    sd = g.std(axis=1, keepdims=True)
    return ((g - mu) / (sd + eps)).reshape(B)


@registry.register("aggregator", "weighted_sum")
def weighted_sum(rewards: Dict[str, jax.Array], weights: Dict[str, float],
                 group_size: int) -> jax.Array:
    total = sum(weights[k] * rewards[k].astype(F32) for k in rewards)
    return group_normalize(total, group_size)


@registry.register("aggregator", "gdpo")
def gdpo(rewards: Dict[str, jax.Array], weights: Dict[str, float],
         group_size: int) -> jax.Array:
    return sum(weights[k] * group_normalize(rewards[k], group_size)
               for k in rewards)


def compute_advantages(strategy: str, rewards: Dict[str, jax.Array],
                       weights: Dict[str, float], group_size: int
                       ) -> jax.Array:
    return registry.build("aggregator", strategy, rewards, weights,
                          group_size)
