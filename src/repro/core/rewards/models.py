"""Concrete reward models.

The paper evaluates with PickScore and Text-Rendering rewards; shipping those
checkpoints is out of scope (DESIGN.md §8), so each is reproduced as a frozen
*synthetic* scorer with the same interface, determinism and cost profile:

* ``pickscore`` — frozen 2-layer MLP preference scorer over (pooled latent,
  pooled condition) — the shape of a CLIP-style preference model.
* ``text_render`` — similarity of the decoded latent to a prompt-derived
  target pattern (the "did the text get rendered" signal).
* ``latent_norm`` — regularity penalty keeping latents on-distribution.
* ``pref_group`` — groupwise pairwise-preference reward (Pref-GRPO): within a
  GRPO group, win-rate under the frozen scorer, group-normalized.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro import registry
from repro.core.rewards.base import GroupwiseRewardModel, PointwiseRewardModel

F32 = jnp.float32


def _pool(x0: jax.Array) -> jax.Array:
    return x0.astype(F32).mean(axis=1)               # (B, ld)


@registry.register("reward", "pickscore")
class PickScoreStub(PointwiseRewardModel):
    """Frozen MLP preference scorer (PickScore, Kirstain et al., 2023)."""

    def __init__(self, model_id: str = "pickscore-base", latent_dim: int = 16,
                 cond_dim: int = 512, hidden: int = 256, seed: int = 7):
        super().__init__(model_id)
        self.latent_dim, self.cond_dim = latent_dim, cond_dim
        self.hidden, self.seed = hidden, seed

    def load_params(self, key: jax.Array) -> Any:
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(self.seed), 3)
        d_in = self.latent_dim + self.cond_dim
        return {
            "w1": jax.random.normal(k1, (d_in, self.hidden), F32)
            / jnp.sqrt(d_in),
            "w2": jax.random.normal(k2, (self.hidden, self.hidden), F32)
            / jnp.sqrt(self.hidden),
            "w3": jax.random.normal(k3, (self.hidden, 1), F32)
            / jnp.sqrt(self.hidden),
        }

    def score(self, x0, cond_meta):
        pooled_c = cond_meta["cond"].astype(F32).mean(axis=1)  # (B, cond_dim)
        h = jnp.concatenate([_pool(x0), pooled_c], axis=-1)
        # jaxlint: disable=R003 — frozen scorer: the loader set_params()s
        # once before the first jitted call and never after (hot-swapping
        # rewards rebuilds the trainer)
        p = self.params
        h = jnp.tanh(h @ p["w1"])
        h = jnp.tanh(h @ p["w2"])
        return (h @ p["w3"])[:, 0]


@registry.register("reward", "text_render")
class TextRenderReward(PointwiseRewardModel):
    """Prompt-conditioned target-pattern similarity (Text-Rendering proxy).

    The target pattern is a deterministic projection of the condition
    embedding into latent space — 'rendering the text' means steering the
    latent toward it; cosine similarity is the reward."""

    def __init__(self, model_id: str = "text-render", latent_dim: int = 16,
                 latent_tokens: int = 64, cond_dim: int = 512, seed: int = 11):
        super().__init__(model_id)
        self.latent_dim, self.latent_tokens = latent_dim, latent_tokens
        self.cond_dim, self.seed = cond_dim, seed

    def load_params(self, key: jax.Array) -> Any:
        k = jax.random.PRNGKey(self.seed)
        return {"proj": jax.random.normal(
            k, (self.cond_dim, self.latent_tokens * self.latent_dim), F32)
            / jnp.sqrt(self.cond_dim)}

    def score(self, x0, cond_meta):
        B = x0.shape[0]
        pooled_c = cond_meta["cond"].astype(F32).mean(axis=1)
        # jaxlint: disable=R003 — frozen scorer: params are set once by the
        # loader before the first jitted call (see PickScoreStub.score)
        target = (pooled_c @ self.params["proj"]).reshape(x0.shape)
        a = x0.astype(F32).reshape(B, -1)
        b = target.reshape(B, -1)
        return jnp.sum(a * b, -1) / (
            jnp.linalg.norm(a, axis=-1) * jnp.linalg.norm(b, axis=-1) + 1e-8)


@registry.register("reward", "latent_norm")
class LatentNormPenalty(PointwiseRewardModel):
    """−(‖x₀‖_rms − 1)²: keeps latents on the unit-variance manifold the VAE
    decoder expects (reward-hacking guard used alongside main rewards)."""

    def __init__(self, model_id: str = "latent-norm"):
        super().__init__(model_id)

    def score(self, x0, cond_meta):
        rms = jnp.sqrt((x0.astype(F32) ** 2).mean(axis=(1, 2)))
        return -(rms - 1.0) ** 2


@registry.register("reward", "pref_group")
class PrefGroupReward(GroupwiseRewardModel):
    """Pairwise-preference groupwise reward (Pref-GRPO, Wang et al., 2025b).

    Each pair (i, j) in a group is compared by a frozen scorer; the reward of
    sample i is its win-rate.  Shares the PickScore backbone by default —
    exercising the loader's deduplication."""

    def __init__(self, model_id: str = "pickscore-base", latent_dim: int = 16,
                 cond_dim: int = 512, hidden: int = 256, seed: int = 7,
                 temperature: float = 10.0):
        super().__init__(model_id)
        self._scorer = PickScoreStub(model_id, latent_dim, cond_dim, hidden,
                                     seed)
        self.temperature = temperature

    def load_params(self, key: jax.Array) -> Any:
        return self._scorer.load_params(key)

    def set_params(self, params: Any) -> None:
        self.params = params
        self._scorer.set_params(params)

    def rank(self, x0_groups, cond_meta):
        P, G = x0_groups.shape[:2]
        flat = x0_groups.reshape((P * G,) + x0_groups.shape[2:])
        s = self._scorer.score(flat, cond_meta).reshape(P, G)
        # soft win-rate: mean over opponents of sigmoid(τ·(s_i − s_j))
        diff = s[:, :, None] - s[:, None, :]                  # (P, G, G)
        win = jax.nn.sigmoid(self.temperature * diff)
        mask = 1.0 - jnp.eye(G)[None]
        return (win * mask).sum(-1) / jnp.maximum(G - 1, 1)
