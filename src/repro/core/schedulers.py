"""SDE schedulers (paper Table 1) behind a unified ``SDESchedulerMixin``.

Rectified-flow convention: ``x_t = (1-t)·x₀ + t·ε``, velocity target
``u = ε − x₀``; sampling integrates t from 1 (noise) down to 0 (data).
Writing ``Δ = t - t_next > 0`` for a step, the paper's Eq. 1 becomes

    x_next = x_t − [v + (σ_t²/2t)(x_t + (1−t)·v)]·Δ + σ_t·√Δ·ε

which is a Gaussian transition — its log-probability (required by GRPO's
policy-gradient ratio) is computed in closed form by ``logprob``.

Dynamics (select via ``sde_type`` — one config knob, paper §3.1):
  flow_sde   σ_t = η·√(t/(1−t))          (Flow-GRPO)
  dance_sde  σ_t = η                      (DanceGRPO)
  cps        coefficient-preserving noise  (FlowCPS; see class docstring)
  ode        σ_t = 0                      (deterministic; NFT/AWM)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import registry

F32 = jnp.float32
_EPS = 1e-4
LOG2PI = jnp.log(2.0 * jnp.pi)


def _sum_dims(x: jax.Array) -> jax.Array:
    """Sum over all but the leading (batch) axis."""
    return x.reshape(x.shape[0], -1).sum(axis=-1)


def gaussian_logpdf(x: jax.Array, mean: jax.Array, std: jax.Array
                    ) -> jax.Array:
    """Per-sample (batch,) log N(x; mean, std²·I), summed over event dims."""
    z = (x.astype(F32) - mean.astype(F32)) / std
    return _sum_dims(-0.5 * (z * z + LOG2PI) - jnp.log(std)
                     * jnp.ones_like(z))


class SDESchedulerMixin:
    """Unified stochastic-sampling interface (paper §2.1 component type)."""

    eta: float

    def timesteps(self, num_steps: int) -> jax.Array:
        """Descending grid t_0=1-ε … t_T=ε, shape (num_steps+1,)."""
        return jnp.linspace(1.0 - _EPS, _EPS, num_steps + 1, dtype=F32)

    # -- per-dynamics hooks ------------------------------------------------
    def sigma(self, t: jax.Array, t_next: jax.Array) -> jax.Array:
        raise NotImplementedError

    def mean_next(self, v: jax.Array, x: jax.Array, t: jax.Array,
                  t_next: jax.Array) -> jax.Array:
        """Deterministic part of the transition (paper Eq. 1 drift)."""
        delta = t - t_next
        sig = self.sigma(t, t_next)
        drift = v + (sig ** 2 / (2.0 * t)) * (x + (1.0 - t) * v)
        return x - drift * delta

    def noise_std(self, t: jax.Array, t_next: jax.Array) -> jax.Array:
        delta = t - t_next
        return self.sigma(t, t_next) * jnp.sqrt(delta)

    # -- unified API ---------------------------------------------------------
    def step_with_eps(self, v: jax.Array, x: jax.Array, t: jax.Array,
                      t_next: jax.Array, eps: jax.Array
                      ) -> Tuple[jax.Array, jax.Array]:
        """One sampling step from externally supplied noise ``eps`` (the
        keyed rollout draws per-request noise; ``step`` draws from a batch
        key).  Returns (x_next, logp (batch,)).  Subclasses with a fused
        kernel override THIS hook, so both rollout flavors dispatch to it."""
        xf, vf = x.astype(F32), v.astype(F32)
        mean = self.mean_next(vf, xf, t, t_next)
        std = self.noise_std(t, t_next)
        stochastic = std > 0
        x_next = jnp.where(stochastic, mean + std * eps.astype(F32), mean)
        safe_std = jnp.maximum(std, 1e-20)
        logp = jnp.where(stochastic,
                         gaussian_logpdf(x_next, mean, safe_std),
                         jnp.zeros(x.shape[0], F32))
        return x_next, logp

    def step(self, v: jax.Array, x: jax.Array, t: jax.Array,
             t_next: jax.Array, key: jax.Array
             ) -> Tuple[jax.Array, jax.Array]:
        """One sampling step. Returns (x_next, logp (batch,))."""
        eps = jax.random.normal(key, x.shape, F32)
        return self.step_with_eps(v, x, t, t_next, eps)

    def logprob(self, v: jax.Array, x: jax.Array, t: jax.Array,
                t_next: jax.Array, x_next: jax.Array) -> jax.Array:
        """log p(x_next | x; v) — recomputed under *current* params for the
        GRPO importance ratio."""
        xf, vf = x.astype(F32), v.astype(F32)
        mean = self.mean_next(vf, xf, t, t_next)
        std = jnp.maximum(self.noise_std(t, t_next), 1e-20)
        return gaussian_logpdf(x_next, mean, std)

    def step_ode(self, v: jax.Array, x: jax.Array, t: jax.Array,
                 t_next: jax.Array) -> jax.Array:
        """Deterministic flow update (used by MixGRPO's ODE segments and by
        the solver-agnostic algorithms)."""
        return x.astype(F32) - v.astype(F32) * (t - t_next)


@registry.register("scheduler", "flow_sde")
@dataclasses.dataclass
class FlowSDEScheduler(SDESchedulerMixin):
    """Flow-GRPO (Liu et al., 2025): σ_t = η·√(t/(1−t)).

    ``t_sigma_max``: σ diverges at t→1; reference implementations shift the
    timestep grid away from 1, which we reproduce by clamping the σ argument
    (documented deviation, DESIGN.md §8).

    ``step_with_eps`` dispatches to the fused Pallas ``sde_step`` kernel on
    TPU (drift + noise + log-density in one VMEM pass) for BOTH the batch-
    keyed ``step`` and the per-request-keyed serving rollout; the jnp path
    is bit-compatible (tests/test_kernels.py)."""
    eta: float = 0.7
    t_sigma_max: float = 0.96

    def sigma(self, t, t_next):
        tc = jnp.clip(t, _EPS, self.t_sigma_max)
        return self.eta * jnp.sqrt(tc / (1.0 - tc))

    def step_with_eps(self, v, x, t, t_next, eps):
        from repro.kernels import ops
        if ops.pallas_enabled():
            return ops.sde_step(v, x, eps, t, t_next, eta=self.eta)
        return super().step_with_eps(v, x, t, t_next, eps)


@registry.register("scheduler", "dance_sde")
@dataclasses.dataclass
class DanceSDEScheduler(SDESchedulerMixin):
    """DanceGRPO (Xue et al., 2025b): σ_t = η (constant)."""
    eta: float = 0.3

    def sigma(self, t, t_next):
        return jnp.full_like(jnp.asarray(t, F32), self.eta)


@registry.register("scheduler", "cps")
@dataclasses.dataclass
class CPSScheduler(SDESchedulerMixin):
    """FlowCPS (Wang & Yu, 2025) — coefficients-preserving sampling.

    Interpretation implemented (documented deviation, DESIGN.md §8): under the
    rectified flow the noise component of the marginal at time s has std s.
    CPS *rotates* that component instead of adding variance: with
    x̂₀ = x − t·v and ε̂ = (x_ode − (1−t')·x̂₀)/t',

        x_next = (1−t')·x̂₀ + t'·(cos(ηπ/2)·ε̂ + sin(ηπ/2)·ε_fresh)

    so the marginal coefficients ((1−t'), t') of the ODE path are preserved
    exactly while injecting noise σ_t = t'·sin(ηπ/2) — matching Table 1's
    recurrence σ_t = σ_{t−1}·sin(ηπ/2) with σ_{t−1} the carried noise scale.
    """
    eta: float = 0.5

    def sigma(self, t, t_next):
        # reported noise scale: σ = t'·sin(ηπ/2) / sqrt(Δ) so noise_std = σ√Δ
        delta = jnp.maximum(t - t_next, 1e-20)
        return t_next * jnp.sin(self.eta * jnp.pi / 2.0) / jnp.sqrt(delta)

    def mean_next(self, v, x, t, t_next):
        c = jnp.cos(self.eta * jnp.pi / 2.0)
        x0_hat = x - t * v
        x_ode = x - v * (t - t_next)
        eps_hat = (x_ode - (1.0 - t_next) * x0_hat) / jnp.maximum(t_next, _EPS)
        return (1.0 - t_next) * x0_hat + t_next * c * eps_hat

    def noise_std(self, t, t_next):
        return t_next * jnp.sin(self.eta * jnp.pi / 2.0)


@registry.register("scheduler", "ode")
@dataclasses.dataclass
class ODEScheduler(SDESchedulerMixin):
    """Deterministic sampling (σ=0) — for DiffusionNFT / AWM (paper §3.2)."""
    eta: float = 0.0

    def sigma(self, t, t_next):
        return jnp.zeros_like(jnp.asarray(t, F32))


def build(sde_type: str, eta: float) -> SDESchedulerMixin:
    return registry.build("scheduler", sde_type, eta=eta)
