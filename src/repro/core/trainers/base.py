"""BaseTrainer — the paper's algorithm-logic component type.

Owns: sampling (rollout), reward computation (MultiRewardLoader), advantage
aggregation, and the optimization step.  Subclasses implement ``loss_fn``
(and may override ``sde_mask`` / ``wants_sde``); everything else — including
distribution, preprocessing and multi-reward handling — is shared, which is
exactly the O(M+N) decoupling the paper claims.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import distributed, optim, perf as perf_lib, registry
from repro.config import (ArchConfig, DistConfig, FlowRLConfig, OptimConfig,
                          PerfConfig, RewardSpec)
from repro.core import schedulers
from repro.core.rewards import MultiRewardLoader, compute_advantages
from repro.core.rollout import Trajectory, group_repeat, rollout
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter

F32 = jnp.float32

# default reward is shape-agnostic (works for any latent geometry)
DEFAULT_REWARDS = (RewardSpec(reward_type="latent_norm", weight=1.0),)


class RLState(NamedTuple):
    params: Any
    opt: optim.AdamWState


class BaseTrainer:
    """Subclass contract: implement ``loss_fn(params, traj, adv, key)``
    (plus one trailing argument per pytree returned by ``update_extras``)."""

    #: scheduler used for rollouts; GRPO variants need an SDE, NFT/AWM
    #: override to force ODE sampling (solver-agnostic algorithms)
    rollout_sde: bool = True

    #: subclasses whose loss reads buffers aliasing RLState (e.g. NFT's
    #: reference policy) must opt out of update-buffer donation
    donate_state_ok: bool = True

    #: subclasses whose loss computes batch-GLOBAL statistics (e.g.
    #: GRPO-Guard's RatioNorm mean) must opt out of gradient-accumulation
    #: microbatching — chunked evaluation would silently turn the statistic
    #: chunk-local and change the training math
    microbatch_safe: bool = True

    def __init__(self, arch_cfg: ArchConfig, flow_cfg: FlowRLConfig,
                 opt_cfg: OptimConfig, *, key: jax.Array,
                 cond_dim: int = 512, dtype=jnp.bfloat16,
                 dist: Optional[DistConfig] = None,
                 perf: Optional[PerfConfig] = None):
        if flow_cfg.group_size < 1:
            raise ValueError(
                f"flow.group_size must be >= 1, got {flow_cfg.group_size}")
        self.cfg = arch_cfg
        self.flow = flow_cfg
        self.opt_cfg = opt_cfg
        self.dist = dist or DistConfig()
        self.perf = perf_lib.validate(perf or PerfConfig())
        # resolved once: the jax.checkpoint offload policy for the scan
        # bodies (None unless perf.remat_offload — plain remat stays the
        # bit-identical program it always was)
        self._remat_policy = perf_lib.remat_policy(self.perf)
        if self.dist.microbatch < 0:
            raise ValueError(
                f"dist.microbatch must be >= 0, got {self.dist.microbatch}")
        if self.dist.microbatch > 1 and not self.microbatch_safe:
            raise ValueError(
                f"{type(self).__name__} computes batch-global loss "
                "statistics and cannot be microbatched: chunked gradient "
                "accumulation would make them chunk-local and change the "
                "training math — set dist.microbatch=0")
        self.mesh = distributed.train_mesh(self.dist)
        self.adapter = FlowAdapter(
            arch_cfg, flow_cfg, cond_dim,
            policy_dtype=perf_lib.resolve_policy_dtype(self.perf))
        # static SDE-branch knowledge for the rollout's dead-branch
        # specialization: pure-ODE trainers (NFT/AWM) never take the SDE
        # branch, trainers that keep the base all-stochastic mask never take
        # the ODE one; only a dynamic mask (MixGRPO) pays for both
        if not self.rollout_sde:
            self.sde_mode = "all_ode"
        elif type(self).sde_mask is BaseTrainer.sde_mask:
            self.sde_mode = "all_sde"
        else:
            self.sde_mode = "mixed"
        sde_type = flow_cfg.sde_type if self.rollout_sde else "ode"
        self.scheduler = schedulers.build(sde_type, flow_cfg.eta)
        k_p, k_r = jax.random.split(key)
        params = params_lib.init(self.adapter.spec(), k_p, dtype)
        self.optimizer = registry.build("optimizer", opt_cfg.optimizer)
        # the PartitionPlan maps every param leaf (and the AdamW moments
        # mirroring it) to a mesh layout — replicated at mp=1, FSDP/expert/
        # head-sharded over "model" otherwise (repro.distributed.sharding)
        self.plan = distributed.partition_plan(self.mesh,
                                               self.adapter.spec())
        self.state = self.place_state(
            RLState(params, self.optimizer.init(params)))
        self.params_sharding = (None if self.plan is None
                                else self.plan.param_shardings())
        self.state_sharding = (None if self.plan is None
                               else self.plan.state_shardings(self.state))
        specs = flow_cfg.rewards or DEFAULT_REWARDS
        self.loader = MultiRewardLoader(specs, k_r)
        # perf.offload_rewards: park the frozen towers in host memory; the
        # rewards/fused jit then takes them as an ARGUMENT (closure capture
        # would bake the trace-time values in as device constants — the
        # PR-2 class, jaxlint R003 — and keep them resident)
        self._reward_store_host = None
        self._reward_prefetch = None
        self._reward_put_sharding = (None if self.mesh is None
                                     else distributed.replicated(self.mesh))
        if self.perf.offload_rewards:
            self._reward_store_host = perf_lib.offload_param_store(
                self.loader)
        self._lr = optim.make_schedule(opt_cfg)
        self._engine = None
        self._sample_jit = distributed.jit_sample(self._sample, self.mesh,
                                                  self.params_sharding)
        self._update_jit = distributed.jit_update(
            self._update, self.mesh, self.state_sharding,
            donate=self.dist.donate_state and self.donate_state_ok,
            extras_sharding=self.update_extras_sharding())
        self._rewards_jit = distributed.jit_rewards(
            functools.partial(self._rewards, group_size=flow_cfg.group_size),
            self.mesh, with_params=self.perf.offload_rewards)
        self._fused_jit = (perf_lib.make_fused_step(self)
                           if self.perf.fuse_step else None)

    def place_state(self, state: RLState) -> RLState:
        """Lay a canonical (host/unsharded) RLState out for this trainer's
        mesh per the PartitionPlan — replicated at ``mp=1``, model-sharded
        otherwise; identity on the single-device path.  Used at init and by
        checkpoint restore (``Experiment.train``), which is what makes
        layouts a runtime choice: a checkpoint written under ``dp=4``
        resumes under ``dp=2×mp=2`` by re-placing here."""
        if self.mesh is None:
            return state
        return jax.device_put(state, self.plan.state_shardings(state))

    # ------------------------------------------------------------- sampling
    def attach_engine(self, engine) -> None:
        """Opt online rollouts into a :class:`repro.serving.ServingEngine`
        (usually ``ServingEngine.for_trainer(self)``): sampling then runs
        the per-request-keyed, bucket-padded, compile-cached path the
        serving stack uses — per-sample results independent of batch
        composition and device layout, and one compile cache shared between
        training rollouts and user-facing serving.  The engine must use
        this trainer's adapter/scheduler/num_steps (and mesh, if any);
        pass ``None`` to detach.  A mismatched scheduler would make the
        update's recomputed log-probs a *different* transition density
        than the one sampled under — silently wrong ratios — so the
        components are validated here, not trusted."""
        if engine is not None:
            if self.perf.fuse_step:
                raise ValueError(
                    "perf.fuse_step and an attached serving engine are "
                    "mutually exclusive: the engine's bucketed rollout is "
                    "host-driven and cannot live inside the fused jit — "
                    "set perf.fuse_step=false or detach the engine")
            if engine.num_steps != self.flow.num_steps:
                raise ValueError(
                    f"engine.num_steps={engine.num_steps} != trainer "
                    f"num_steps={self.flow.num_steps}")
            if engine.scheduler != self.scheduler:
                raise ValueError(
                    f"engine scheduler {engine.scheduler!r} != trainer "
                    f"scheduler {self.scheduler!r} — rollout dynamics and "
                    "the update's logprob must match")
            if engine.mesh != self.mesh:
                raise ValueError(
                    f"engine mesh {engine.mesh} != trainer mesh "
                    f"{self.mesh} — build via ServingEngine.for_trainer")
        self._engine = engine

    def sde_mask(self, it: int) -> Optional[jnp.ndarray]:
        return None  # default: all steps stochastic (or all ODE)

    def _sample(self, params, cond: jax.Array, key: jax.Array,
                sde_mask) -> Trajectory:
        return rollout(self.adapter, params, cond, key, self.scheduler,
                       self.flow.num_steps, sde_mask,
                       sde_mode=self.sde_mode, remat=self.perf.remat,
                       remat_policy=self._remat_policy)

    def sample(self, params, cond: jax.Array, key: jax.Array, it: int = 0
               ) -> Trajectory:
        """cond: (P, Lc, D) prompt embeddings -> grouped trajectories."""
        cond_g = group_repeat(cond, self.flow.group_size)
        # the downstream *update* still shards/chunks the trajectory, so the
        # divisibility contract holds on both sampling paths
        distributed.check_batch_divisible(cond_g.shape[0], self.mesh,
                                          self.dist.microbatch)
        mask = self.sde_mask(it)
        if mask is None:     # concrete mask: jit shardings need a real leaf
            mask = jnp.ones((self.flow.num_steps,), bool)
        if self._engine is not None:
            return self._engine.rollout(params, cond_g, key, sde_mask=mask)
        return self._sample_jit(params, cond_g, key, mask)

    # -------------------------------------------------------------- rewards
    @property
    def offloads_rewards(self) -> bool:
        """Whether the frozen reward-tower params live in host memory
        (``perf.offload_rewards``) and are threaded into the rewards/fused
        jit as arguments."""
        return self._reward_store_host is not None

    def prefetch_reward_params(self) -> None:
        """Start the async H2D copy of the host-offloaded reward towers
        (no-op when ``perf.offload_rewards`` is off or a prefetch is
        already pending).  The TrainLoop calls this right after each
        dispatch so the transfer overlaps the in-flight step's device
        work; the next ``step`` consumes it via ``_take_reward_params``."""
        if self._reward_store_host is None or \
                self._reward_prefetch is not None:
            return
        self._reward_prefetch = perf_lib.prefetch_tree(
            self._reward_store_host, self._reward_put_sharding)

    def _take_reward_params(self):
        """The device copy of the reward towers for this step: the pending
        prefetch if the loop armed one, else a fresh (synchronously
        enqueued, still async) transfer."""
        rp, self._reward_prefetch = self._reward_prefetch, None
        if rp is None:
            rp = perf_lib.prefetch_tree(self._reward_store_host,
                                        self._reward_put_sharding)
        return rp

    def _rewards(self, x0: jax.Array, cond_meta: Dict, reward_params=None,
                 *, group_size: int
                 ) -> Tuple[Dict[str, jax.Array], jax.Array,
                            Dict[str, jax.Array]]:
        """Returns (raw rewards, advantages, reward stats) — the stats (the
        weight_map-weighted ``reward_mean`` the optimizer ascends plus the
        per-reward means) are computed ON DEVICE here, inside the
        rewards/fused jit, so ``step`` never dispatches per-metric eager
        reductions.  ``reward_params`` (``perf.offload_rewards``) is the
        host-offloaded tower store threaded in as a jit argument; None
        keeps the historical resident-constant path."""
        rew = self.loader.compute_all(x0, cond_meta, group_size=group_size,
                                      params=reward_params)
        adv = compute_advantages(self.flow.advantage_agg, rew,
                                 self.loader.weight_map(), group_size)
        weights = self.loader.weight_map()
        stats = {f"reward/{name}": r.astype(F32).mean()
                 for name, r in rew.items()}
        stats["reward_mean"] = sum(weights[name] * stats[f"reward/{name}"]
                                   for name in rew)
        return rew, adv, stats

    # --------------------------------------------------------------- update
    def loss_fn(self, params, traj: Trajectory, adv: jax.Array,
                key: jax.Array, *extras
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        raise NotImplementedError

    def update_extras(self) -> Tuple:
        """Auxiliary pytrees threaded into the jitted update as *arguments*
        (never closure-captured: jit would bake them in as constants at
        trace time, silently freezing later updates — the NFT reference-
        policy bug).  Called by ``step`` before the state is replaced, so
        entries derived from ``self.state`` see the behavior policy."""
        return ()

    def update_extras_sharding(self):
        """Mesh layout of the ``update_extras()`` tuple for the jitted
        update — None replicates.  Trainers whose extras alias param-shaped
        trees (NFT's ref_params) override this so the update jit accepts
        them in their placed (model-sharded) layout under ``mp>1``."""
        return None

    def _update(self, state: RLState, traj: Trajectory, adv: jax.Array,
                key: jax.Array, extras: Tuple = ()
                ) -> Tuple[RLState, Dict[str, jax.Array]]:
        k = self.dist.microbatch
        if k and k > 1:
            (loss, aux), grads = distributed.accumulated_value_and_grad(
                self.loss_fn, state.params, traj, adv, key, extras, k)
        else:
            (loss, aux), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(state.params, traj, adv, key,
                                            *extras)
        grads, gnorm = optim.clip_by_global_norm(grads,
                                                 self.opt_cfg.grad_clip)
        lr = self._lr(state.opt.step)
        new_p, new_opt = self.optimizer.update(state.params, grads, state.opt,
                                               self.opt_cfg, lr)
        aux = dict(aux)
        aux.update(loss=loss, grad_norm=gnorm, lr=lr)
        return RLState(new_p, new_opt), aux

    # ------------------------------------------------------------ iteration
    def step(self, cond: jax.Array, key: jax.Array, it: int = 0
             ) -> Dict[str, jax.Array]:
        """One full RL iteration: rollout -> rewards -> advantages -> update.

        cond: (P, Lc, cond_dim) prompt embeddings (from the preprocessing
        cache or a live encoder — the trainer doesn't know which: §2.2).

        Returns a flat dict of DEVICE scalars (including the weighted
        ``reward_mean`` matching the advantage aggregation — EarlyStop and
        the JSON log track the same objective the optimizer ascends);
        callers fetch them with one ``jax.device_get``, not one transfer
        per metric.  With ``perf.fuse_step`` the whole iteration is a
        single donated jit (``repro.perf.fused``)."""
        if self._fused_jit is not None and self._engine is None:
            cond_g = group_repeat(cond, self.flow.group_size)
            distributed.check_batch_divisible(cond_g.shape[0], self.mesh,
                                              self.dist.microbatch)
            mask = self.sde_mask(it)
            if mask is None:
                mask = jnp.ones((self.flow.num_steps,), bool)
            extras = self.update_extras()
            if self.offloads_rewards:
                self.state, metrics = self._fused_jit(
                    self.state, cond_g, key, jnp.int32(it), mask, extras,
                    self._take_reward_params())
            else:
                self.state, metrics = self._fused_jit(
                    self.state, cond_g, key, jnp.int32(it), mask, extras)
            return metrics
        k_s, k_u = jax.random.split(jax.random.fold_in(key, it))
        traj = self.sample(self.state.params, cond, k_s, it)
        cond_meta = {"cond": traj.cond}
        if self.offloads_rewards:
            _, adv, reward_stats = self._rewards_jit(
                traj.x0, cond_meta, self._take_reward_params())
        else:
            _, adv, reward_stats = self._rewards_jit(traj.x0, cond_meta)
        extras = self.update_extras()
        self.state, metrics = self._update_jit(self.state, traj, adv, k_u,
                                               extras)
        metrics.update(reward_stats)
        return metrics

    # ------------------------------------------------------------- helpers
    def velocity(self, params, x, t, cond):
        # loss-side velocity: block remat threads the backbone's per-layer
        # checkpointing through the forward the backward will rematerialize
        return self.adapter.velocity(
            params, x, t, cond, remat=perf_lib.block_remat(self.perf.remat))

    def memory_stats(self, cond: jax.Array) -> Dict[str, Dict]:
        """``compiled.memory_analysis()`` byte counts of the jitted update
        (and the fused step, when enabled) for a (P, Lc, cond_dim) prompt
        batch, plus a ``"state"`` entry with the RLState's canonical total
        vs per-device bytes under the active PartitionPlan — the FSDP
        memory win, visible in ``perf.log_memory``.  See
        ``repro.perf.memory``.  AOT introspection only: nothing runs, no
        live buffer is donated."""
        return perf_lib.update_memory(self, cond)

    def sample_timesteps(self, key: jax.Array, batch: int) -> jax.Array:
        """Timestep sampling strategies for the solver-agnostic algorithms
        (paper §3.2): uniform | logit_normal | discrete."""
        how = self.flow.timestep_sampling
        if how == "uniform":
            return jax.random.uniform(key, (batch,), F32, 0.02, 0.98)
        if how == "logit_normal":
            return jax.nn.sigmoid(jax.random.normal(key, (batch,), F32))
        if how == "discrete":
            grid = self.scheduler.timesteps(self.flow.num_steps)[:-1]
            idx = jax.random.randint(key, (batch,), 0, grid.shape[0])
            return grid[idx]
        raise ValueError(f"unknown timestep_sampling {how!r}")
