"""BaseTrainer — the paper's algorithm-logic component type.

Owns: sampling (rollout), reward computation (MultiRewardLoader), advantage
aggregation, and the optimization step.  Subclasses implement ``loss_fn``
(and may override ``sde_mask`` / ``wants_sde``); everything else — including
distribution, preprocessing and multi-reward handling — is shared, which is
exactly the O(M+N) decoupling the paper claims.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import optim, registry
from repro.config import ArchConfig, FlowRLConfig, OptimConfig, RewardSpec
from repro.core import schedulers
from repro.core.rewards import MultiRewardLoader, compute_advantages
from repro.core.rollout import Trajectory, group_repeat, rollout
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter

F32 = jnp.float32

# default reward is shape-agnostic (works for any latent geometry)
DEFAULT_REWARDS = (RewardSpec(reward_type="latent_norm", weight=1.0),)


class RLState(NamedTuple):
    params: Any
    opt: optim.AdamWState


class BaseTrainer:
    """Subclass contract: implement ``loss_fn(params, traj, adv, key)``."""

    #: scheduler used for rollouts; GRPO variants need an SDE, NFT/AWM
    #: override to force ODE sampling (solver-agnostic algorithms)
    rollout_sde: bool = True

    def __init__(self, arch_cfg: ArchConfig, flow_cfg: FlowRLConfig,
                 opt_cfg: OptimConfig, *, key: jax.Array,
                 cond_dim: int = 512, dtype=jnp.bfloat16):
        self.cfg = arch_cfg
        self.flow = flow_cfg
        self.opt_cfg = opt_cfg
        self.adapter = FlowAdapter(arch_cfg, flow_cfg, cond_dim)
        sde_type = flow_cfg.sde_type if self.rollout_sde else "ode"
        self.scheduler = schedulers.build(sde_type, flow_cfg.eta)
        k_p, k_r = jax.random.split(key)
        params = params_lib.init(self.adapter.spec(), k_p, dtype)
        self.optimizer = registry.build("optimizer", opt_cfg.optimizer)
        self.state = RLState(params, self.optimizer.init(params))
        specs = flow_cfg.rewards or DEFAULT_REWARDS
        self.loader = MultiRewardLoader(specs, k_r)
        self._lr = optim.make_schedule(opt_cfg)
        self._sample_jit = jax.jit(self._sample)
        self._update_jit = jax.jit(self._update)
        self._rewards_jit = jax.jit(functools.partial(
            self._rewards, group_size=flow_cfg.group_size))

    # ------------------------------------------------------------- sampling
    def sde_mask(self, it: int) -> Optional[jnp.ndarray]:
        return None  # default: all steps stochastic (or all ODE)

    def _sample(self, params, cond: jax.Array, key: jax.Array,
                sde_mask) -> Trajectory:
        return rollout(self.adapter, params, cond, key, self.scheduler,
                       self.flow.num_steps, sde_mask)

    def sample(self, params, cond: jax.Array, key: jax.Array, it: int = 0
               ) -> Trajectory:
        """cond: (P, Lc, D) prompt embeddings -> grouped trajectories."""
        cond_g = group_repeat(cond, self.flow.group_size)
        return self._sample_jit(params, cond_g, key, self.sde_mask(it))

    # -------------------------------------------------------------- rewards
    def _rewards(self, x0: jax.Array, cond_meta: Dict, *, group_size: int
                 ) -> Tuple[Dict[str, jax.Array], jax.Array]:
        rew = self.loader.compute_all(x0, cond_meta, group_size=group_size)
        adv = compute_advantages(self.flow.advantage_agg, rew,
                                 self.loader.weight_map(), group_size)
        return rew, adv

    # --------------------------------------------------------------- update
    def loss_fn(self, params, traj: Trajectory, adv: jax.Array,
                key: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        raise NotImplementedError

    def _update(self, state: RLState, traj: Trajectory, adv: jax.Array,
                key: jax.Array) -> Tuple[RLState, Dict[str, jax.Array]]:
        (loss, aux), grads = jax.value_and_grad(
            self.loss_fn, has_aux=True)(state.params, traj, adv, key)
        grads, gnorm = optim.clip_by_global_norm(grads,
                                                 self.opt_cfg.grad_clip)
        lr = self._lr(state.opt.step)
        new_p, new_opt = self.optimizer.update(state.params, grads, state.opt,
                                               self.opt_cfg, lr)
        aux = dict(aux)
        aux.update(loss=loss, grad_norm=gnorm, lr=lr)
        return RLState(new_p, new_opt), aux

    # ------------------------------------------------------------ iteration
    def step(self, cond: jax.Array, key: jax.Array, it: int = 0
             ) -> Dict[str, jax.Array]:
        """One full RL iteration: rollout -> rewards -> advantages -> update.

        cond: (P, Lc, cond_dim) prompt embeddings (from the preprocessing
        cache or a live encoder — the trainer doesn't know which: §2.2)."""
        k_s, k_u = jax.random.split(jax.random.fold_in(key, it))
        traj = self.sample(self.state.params, cond, k_s, it)
        cond_meta = {"cond": traj.cond}
        rewards, adv = self._rewards_jit(traj.x0, cond_meta)
        self.state, metrics = self._update_jit(self.state, traj, adv, k_u)
        metrics["reward_mean"] = sum(r.mean() for r in rewards.values())
        for name, r in rewards.items():
            metrics[f"reward/{name}"] = r.mean()
        return metrics

    # ------------------------------------------------------------- helpers
    def velocity(self, params, x, t, cond):
        return self.adapter.velocity(params, x, t, cond)

    def sample_timesteps(self, key: jax.Array, batch: int) -> jax.Array:
        """Timestep sampling strategies for the solver-agnostic algorithms
        (paper §3.2): uniform | logit_normal | discrete."""
        how = self.flow.timestep_sampling
        if how == "uniform":
            return jax.random.uniform(key, (batch,), F32, 0.02, 0.98)
        if how == "logit_normal":
            return jax.nn.sigmoid(jax.random.normal(key, (batch,), F32))
        if how == "discrete":
            grid = self.scheduler.timesteps(self.flow.num_steps)[:-1]
            idx = jax.random.randint(key, (batch,), 0, grid.shape[0])
            return grid[idx]
        raise ValueError(f"unknown timestep_sampling {how!r}")
