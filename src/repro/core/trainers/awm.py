"""Advantage Weighted Matching (Xue et al., 2025a) — aligns RL with the
pretraining objective by weighting the standard velocity-matching loss with
per-sample advantages (paper Eq. 3):

    L = E[ A(x₀) · ‖v_θ(x_t, t) − (ε − x₀)‖² ]

Solver-agnostic: trajectories come from any ODE solver; the loss touches only
the forward process.  Advantages are clipped to a bounded range for
stability (negative advantages *increase* velocity error on bad samples,
which is the policy-gradient-aligned direction but diverges if unbounded).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import registry
from repro.core.rollout import Trajectory
from repro.core.trainers.base import BaseTrainer

F32 = jnp.float32


@registry.register("trainer", "awm")
class AWMTrainer(BaseTrainer):
    rollout_sde = False           # ODE rollouts

    adv_clip: float = 3.0

    def loss_fn(self, params, traj: Trajectory, adv: jax.Array,
                key: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x0 = traj.x0
        cond = traj.cond
        B = x0.shape[0]
        k_t, k_eps = jax.random.split(key)
        t = self.sample_timesteps(k_t, B)
        eps = jax.random.normal(k_eps, x0.shape, F32)
        x_t = (1.0 - t)[:, None, None] * x0 + t[:, None, None] * eps
        target = eps - x0

        v = self.velocity(params, x_t, t, cond)
        se = ((v - target) ** 2).mean(axis=(1, 2))          # (B,)
        a = jnp.clip(adv, -self.adv_clip, self.adv_clip)
        loss = (a * se).mean()
        aux = {"vel_err": jnp.sqrt(se.mean()), "adv_clip_frac":
               (jnp.abs(adv) > self.adv_clip).astype(F32).mean()}
        return loss, aux
