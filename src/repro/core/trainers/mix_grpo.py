"""MixGRPO (Li et al., 2025) — *Flow-GRPO-Fast*: SDE on only a small window
of timesteps (1–2 by default), ODE everywhere else.  Cuts both the sampling
noise-injection cost and, more importantly, the training cost: the policy
gradient only needs velocity recomputation at the SDE steps.  The window can
slide over training (``sde_window_shift_every``) so all timesteps eventually
receive gradient signal.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import registry
from repro.core.rollout import mix_sde_mask
from repro.core.trainers.grpo import FlowGRPOTrainer


@registry.register("trainer", "mix_grpo")
class MixGRPOTrainer(FlowGRPOTrainer):
    rollout_sde = True

    def sde_mask(self, it: int) -> jnp.ndarray:
        shift = 0
        if self.flow.sde_window_shift_every:
            shift = it // self.flow.sde_window_shift_every
        return mix_sde_mask(self.flow.num_steps, self.flow.sde_window, shift)
