"""Flow-GRPO trainer (Liu et al., 2025) — PPO-style clipped policy gradient
over SDE transition log-probabilities, with group-relative advantages.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import registry
from repro.core.rollout import Trajectory, checkpoint_scan_body, \
    name_residual
from repro.core.trainers.base import BaseTrainer

F32 = jnp.float32


@registry.register("trainer", "flow_grpo")
class FlowGRPOTrainer(BaseTrainer):
    rollout_sde = True

    def ratio_transform(self, ratio: jax.Array, t_index: jax.Array,
                        is_sde: jax.Array) -> jax.Array:
        """Hook for GRPO-Guard's RatioNorm; identity here.
        ratio: (B,) at one timestep."""
        return ratio

    def loss_fn(self, params, traj: Trajectory, adv: jax.Array,
                key: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        T = self.flow.num_steps
        clip = self.flow.clip_range
        cond = traj.cond
        B = cond.shape[0]

        from repro.kernels import ops
        use_kernel = ops.pallas_enabled() and type(self).ratio_transform \
            is FlowGRPOTrainer.ratio_transform and self.flow.kl_coef == 0.0

        def per_step(carry, inp):
            x_t, x_next, t, t_next, tb, logp_old, is_sde, t_idx = inp
            # the body's dominant residual: under perf.remat_offload it is
            # saved to host memory instead of recomputed in the backward
            v = name_residual(self.velocity(params, x_t, tb, cond),
                              self._remat_policy)
            logp_new = self.scheduler.logprob(v, x_t, t, t_next, x_next)
            if use_kernel:
                # fused ratio/clip/advantage Pallas kernel (vanilla GRPO path;
                # Guard's RatioNorm and KL use the jnp path); closed-form
                # PPO-clip VJP — see kernels/grpo_loss.py
                step_loss, frac_clipped = ops.grpo_loss_trainable(
                    logp_new, logp_old, adv, clip=clip)
            else:
                ratio = jnp.exp(jnp.clip(logp_new - logp_old, -20.0, 20.0))
                ratio = self.ratio_transform(ratio, t_idx, is_sde)
                unclipped = ratio * adv
                clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
                step_loss = -jnp.minimum(unclipped, clipped)
                # KL penalty against the behaviour policy (optional)
                step_loss = step_loss + self.flow.kl_coef * 0.5 * (
                    logp_new - logp_old) ** 2
                frac_clipped = (jnp.abs(ratio - 1.0) > clip).astype(F32)
            step_loss = jnp.where(is_sde, step_loss,
                                  jnp.zeros_like(step_loss))
            frac_clipped = jnp.where(is_sde, frac_clipped, 0.0)
            loss_sum, clip_sum, n_sde = carry
            return ((loss_sum + step_loss.mean(),
                     clip_sum + frac_clipped.mean(),
                     n_sde + is_sde.astype(F32)), None)

        # remat: checkpointing the scan body keeps only one timestep's
        # backbone activations live in the backward (scan-body checkpoint
        # is bit-exact on XLA:CPU — see repro.perf); the (T, B) timestep
        # batch is hoisted out of the body as scan input
        per_step = checkpoint_scan_body(per_step, self.perf.remat,
                                        policy=self._remat_policy)
        t_indices = jnp.arange(T)
        tbs = jnp.broadcast_to(traj.ts[:-1, None], (T, B)).astype(F32)
        (loss_sum, clip_sum, n_sde), _ = jax.lax.scan(
            per_step, (jnp.zeros((), F32),) * 3,
            (traj.xs[:-1], traj.xs[1:], traj.ts[:-1], traj.ts[1:], tbs,
             traj.logps, traj.sde_mask, t_indices))
        denom = jnp.maximum(n_sde, 1.0)
        loss = loss_sum / denom
        aux = {"clip_frac": clip_sum / denom, "adv_std": adv.std()}
        return loss, aux
