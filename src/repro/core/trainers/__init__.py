from repro.core.trainers.base import BaseTrainer, RLState
from repro.core.trainers.grpo import FlowGRPOTrainer
from repro.core.trainers.mix_grpo import MixGRPOTrainer
from repro.core.trainers.grpo_guard import GRPOGuardTrainer
from repro.core.trainers.nft import DiffusionNFTTrainer
from repro.core.trainers.awm import AWMTrainer

__all__ = ["BaseTrainer", "RLState", "FlowGRPOTrainer", "MixGRPOTrainer",
           "GRPOGuardTrainer", "DiffusionNFTTrainer", "AWMTrainer"]
