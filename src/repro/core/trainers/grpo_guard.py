"""GRPO-Guard (Wang et al., 2025a) — mitigates the *negatively-biased ratio
distribution* of flow-SDE formulations.

The SDE transition variance is timestep-dependent, so the importance ratio
ρ = exp(logp_new − logp_old) is systematically biased low at high-noise
timesteps; naive clipping then asymmetrically suppresses positive updates
(implicit over-optimization / reward hacking).  GRPO-Guard applies
**RatioNorm** — recentring each timestep's ratio distribution by its batch
mean (stop-gradient) — plus the standard regulated clip, so every timestep
contributes an unbiased, comparable gradient.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import registry
from repro.core.trainers.grpo import FlowGRPOTrainer

F32 = jnp.float32


@registry.register("trainer", "grpo_guard")
class GRPOGuardTrainer(FlowGRPOTrainer):
    rollout_sde = True
    # RatioNorm is a batch-GLOBAL statistic: microbatched chunks would each
    # recentre by their own chunk mean, silently weakening the correction
    microbatch_safe = False

    def ratio_transform(self, ratio: jax.Array, t_index: jax.Array,
                        is_sde: jax.Array) -> jax.Array:
        # RatioNorm: divide by the batch-mean ratio at this timestep.
        # stop_gradient: the correction is a statistic, not a policy term.
        mean = jax.lax.stop_gradient(ratio.mean())
        return ratio / jnp.maximum(mean, 1e-6)
