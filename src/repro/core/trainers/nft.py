"""DiffusionNFT (Zheng et al., 2025) — online RL on the *forward* process.

No likelihoods, no SDE sampling: trajectories are generated with any ODE
solver (solver-agnostic, paper §3.2); training contrasts an implicit positive
and negative policy on the forward flow-matching objective (paper Eq. 2):

    L = E[ r·‖v⁺_θ(x_t,c,t) − v‖² + (1−r)·‖v⁻_θ(x_t,c,t) − v‖² ]

with v = ε − x₀ the forward-process velocity target and r ∈ [0,1] a
normalized reward.  Implementation note (DESIGN.md §8): the implicit negative
is realised by reflection about a reference policy, v⁻ = 2·v_ref − v_θ, so
pushing v⁺ toward the target for good samples and the *reflection* toward it
for bad ones yields the contrastive improvement direction without likelihood
estimation.

The reference is the *behavior* policy — the params that sampled the current
round — refreshed every iteration (online NFT).  A reference frozen at
initialization anchors the loss's per-sample optimum
``v* = r·v_target + (1−r)·(2·v_ref − v_target)`` to the init policy, so
improvement stalls at a fixed point one covariance-step from init instead of
compounding (the reward-doesn't-improve bug).  Mechanically the reference
must be threaded through the jitted update as an *argument*
(``update_extras``): reading ``self.ref_params`` inside a jitted function
bakes the init values in as trace-time constants, silently freezing the
reference no matter what the attribute is later set to.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro import registry
from repro.core.rollout import Trajectory
from repro.core.trainers.base import BaseTrainer

F32 = jnp.float32


@registry.register("trainer", "nft")
class DiffusionNFTTrainer(BaseTrainer):
    rollout_sde = False           # ODE rollouts (Table 1 row "ODE")
    donate_state_ok = False       # ref aliases state.params inside the update

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        # reference policy for the implicit negative; tracks the behavior
        # policy (refreshed by update_extras each round)
        self.ref_params = self.state.params

    def update_extras(self):
        self.ref_params = self.state.params    # behavior policy this round
        return (self.ref_params,)

    def update_extras_sharding(self):
        # ref_params alias the placed live params, so under mp>1 they reach
        # the update jit model-sharded per the PartitionPlan, not replicated
        return (None if self.params_sharding is None
                else (self.params_sharding,))

    def loss_fn(self, params, traj: Trajectory, adv: jax.Array,
                key: jax.Array, ref_params=None
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        if ref_params is None:        # direct (un-jitted) calls, e.g. tests
            # jaxlint: disable=R003 — fallback for un-jitted direct calls
            # only; the jitted path threads ref_params through
            # update_extras() as a real argument (the PR-2 fix)
            ref_params = self.ref_params
        x0 = traj.x0
        cond = traj.cond
        B = x0.shape[0]
        k_t, k_eps = jax.random.split(key)
        t = self.sample_timesteps(k_t, B)
        eps = jax.random.normal(k_eps, x0.shape, F32)
        x_t = (1.0 - t)[:, None, None] * x0 + t[:, None, None] * eps
        target = eps - x0

        v_pos = self.velocity(params, x_t, t, cond)
        v_ref = jax.lax.stop_gradient(
            self.velocity(ref_params, x_t, t, cond))
        v_neg = 2.0 * v_ref - v_pos

        # r in [0,1] from group-normalized advantages
        r = jax.nn.sigmoid(adv)[:, None, None]
        se_pos = (v_pos - target) ** 2
        se_neg = (v_neg - target) ** 2
        loss = (r * se_pos + (1.0 - r) * se_neg).mean()
        aux = {"r_mean": r.mean(),
               "vel_err": jnp.sqrt(se_pos.mean())}
        return loss, aux
