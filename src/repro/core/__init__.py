# The paper's primary contribution: registry-decoupled RL training for
# flow-matching models — schedulers (Table 1), trainers (§3), multi-reward
# system (§2.3), preprocessing-based memory optimization (§2.2).
from repro.core import schedulers, rollout, preprocess
from repro.core.rewards import MultiRewardLoader
from repro.core.trainers import (AWMTrainer, BaseTrainer, DiffusionNFTTrainer,
                                 FlowGRPOTrainer, GRPOGuardTrainer,
                                 MixGRPOTrainer, RLState)

__all__ = ["schedulers", "rollout", "preprocess", "MultiRewardLoader",
           "BaseTrainer", "RLState", "FlowGRPOTrainer", "MixGRPOTrainer",
           "GRPOGuardTrainer", "DiffusionNFTTrainer", "AWMTrainer"]
