"""Logical-axis sharding rules.

Every parameter and activation in the framework carries a tuple of *logical*
axis names.  A rule table maps logical names -> mesh axes, which yields a
``PartitionSpec``.  This keeps the model code mesh-agnostic: the same model
runs on 1 CPU device, a 256-chip pod, or the 512-chip two-pod mesh purely by
swapping the rule table.

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``.

Baseline layout (documented in DESIGN.md §5):
  * batch            -> ("pod", "data")
  * attention heads, FFN hidden, expert hidden, vocab -> "model"
  * parameters additionally FSDP-sharded over "data" on their embed axis for
    training shapes (zero-3 style)
  * long-context decode: KV cache sequence -> "data" (distributed flash-decode)
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Axes = Tuple[Optional[str], ...]
RuleTable = Dict[str, Union[str, Tuple[str, ...], None]]


def _moe_mode() -> str:
    import os
    return os.environ.get("REPRO_MOE_MODE", "tensor")


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes the batch dim is sharded over."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def param_rules(mesh: Mesh, *, fsdp: bool = True, train: bool = True) -> RuleTable:
    """Rules for parameter logical axes."""
    fsdp_axis = "data" if (fsdp and "data" in mesh.axis_names) else None
    return {
        "embed": fsdp_axis,        # d_model rows of big matrices (zero-3)
        "embed_r": fsdp_axis,      # d_model as the output dim (w_down, wo):
                                   # same zero-3 treatment for params; the
                                   # activation rule maps it to None
        "heads": "model",
        "kv_heads": "model",
        "q_lora": None,
        "kv_lora": None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": None,           # tensor/dense: experts replicated over mesh
        "experts_mdl": "model",    # ep_model: experts sharded over model axis
        "moe_f": None,             # per-expert hidden dim in ep_model mode
        # expert-weight d_model dims: always fsdp-sharded in storage; see
        # gathered_param_rules for the at-use layout per mode
        "moe_in": fsdp_axis,
        "moe_out": fsdp_axis,
        "layers": None,
        "groups": None,
        "state": None,
        "conv": None,
        "inner": "model",          # ssm d_inner
        "ssm_heads": "model",
        "norm": None,
        "latent": None,
        "time": None,
    }


def act_rules(mesh: Mesh, *, seq_shard: bool = False) -> RuleTable:
    """Rules for activation / cache logical axes."""
    b = batch_axes(mesh)
    return {
        "batch": b if b else None,
        "seq": None,
        "cache_seq": ("data" if (seq_shard and "data" in mesh.axis_names) else None),
        "embed": None,
        "embed_r": None,
        "heads": "model",
        "kv_heads": "model",
        "kv_lora": None,
        "head_dim": None,
        "mlp": "model",
        "vocab": "model",
        "experts": None,
        "experts_mdl": "model",    # dispatch-buffer all-to-all target
        "moe_f": None,
        "moe_in": None,
        "moe_out": None,
        "state": None,
        "inner": "model",
        "ssm_heads": "model",
        "latent": None,
        "cond": None,
        "group": None,
        "time": None,
        "scalar": None,
    }


def pspec(axes: Axes, rules: RuleTable) -> PartitionSpec:
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        else:
            m = rules.get(a, None)
            out.append(m)
    # PartitionSpec trailing Nones are fine; keep explicit length
    return PartitionSpec(*out)


def named(mesh: Mesh, axes: Axes, rules: RuleTable) -> NamedSharding:
    return NamedSharding(mesh, pspec(axes, rules))


def tree_pspecs(axes_tree, rules: RuleTable):
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    return jax.tree.map(
        lambda ax: pspec(ax, rules),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def tree_shardings(mesh: Mesh, axes_tree, rules: RuleTable):
    return jax.tree.map(
        lambda ax: NamedSharding(mesh, pspec(ax, rules)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


# ---------------------------------------------------------------------------
# Weight-gathered FSDP (zero-3 done right)
#
# With parameters fsdp-sharded on their embed axis and layers driven by
# lax.scan, XLA's SPMD partitioner may choose to keep the *weight* shards in
# place and instead all-gather the ACTIVATIONS to global batch + all-reduce
# the d-partial matmul outputs — catastrophically more traffic (observed:
# ~1.3 TB/step/device on smollm train_4k).  The fix is the MaxText approach:
# constrain the per-layer weight slices to the GATHERED layout inside the
# scan body, so each layer all-gathers its (small) weights over the data
# axis and activations stay batch-sharded.
#
# The constraint is installed per-trace via set_param_gather(); model code
# calls constrain_params(blk_params, axes_tree) at the top of each block.
# ---------------------------------------------------------------------------

_GATHER_CTX: dict = {"mesh": None, "param_rules": None, "act_rules": None}


def gathered_param_rules(mesh: Mesh) -> RuleTable:
    """Layout of a weight slice while it is being USED: model-sharded axes
    stay sharded; the fsdp (data) shard is gathered — EXCEPT expert weights,
    which stay fsdp-sharded (gathering all E experts per layer would move
    E/top_k more bytes than the activation traffic it saves)."""
    r = param_rules(mesh, fsdp=False)
    if _moe_mode() != "ep_model":
        # tensor/dense: keep expert weights fsdp-sharded (skip the gather)
        stored = param_rules(mesh, fsdp=True)
        r["moe_in"] = stored["moe_in"]
        r["moe_out"] = stored["moe_out"]
    # ep_model: experts live on the model axis with full f, so gathering the
    # (1/16-sized) d shards at use is cheap and keeps the matmul local
    return r


def set_param_gather(mesh: Optional[Mesh],
                     prules: Optional[RuleTable] = None,
                     arules: Optional[RuleTable] = None) -> None:
    """Install (or clear, with mesh=None) the per-trace constraint context."""
    _GATHER_CTX["mesh"] = mesh
    _GATHER_CTX["param_rules"] = (
        prules if prules is not None else
        (gathered_param_rules(mesh) if mesh is not None else None))
    _GATHER_CTX["act_rules"] = (
        arules if arules is not None else
        (act_rules(mesh) if mesh is not None else None))


def _constrain(x, axes: Axes, rules: RuleTable, mesh: Mesh):
    ax = tuple(axes)
    if len(ax) >= x.ndim:      # scan slices drop leading stacking axes
        ax = ax[len(ax) - x.ndim:]
    else:
        ax = (None,) * (x.ndim - len(ax)) + ax
    spec = pspec(ax, rules)
    # never force a non-divisible dim onto a mesh axis (XLA would pad and
    # recombine with full-tensor collectives — e.g. kv_heads=5 on model=16)
    cleaned = []
    for dim, entry in zip(x.shape, tuple(spec) + (None,) * (x.ndim - len(spec))):
        if entry is None:
            cleaned.append(None)
            continue
        names_ = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for n in names_:
            size *= mesh.shape[n]
        cleaned.append(entry if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*cleaned)))


def constrain_params(params, axes_tree):
    """Apply the gathered-weight constraint if one is installed.

    ``params`` drives the map (array leaves); ``axes_tree`` holds a logical-
    axes tuple at each corresponding leaf position (tuples are treated as
    leaves by flatten-up-to)."""
    mesh, rules = _GATHER_CTX["mesh"], _GATHER_CTX["param_rules"]
    if mesh is None:
        return params
    return jax.tree.map(lambda p, ax: _constrain(p, ax, rules, mesh),
                        params, axes_tree)


def constrain_act(x, axes: Axes):
    """Pin an activation to the canonical layout (batch-sharded)."""
    mesh, rules = _GATHER_CTX["mesh"], _GATHER_CTX["act_rules"]
    if mesh is None:
        return x
    return _constrain(x, axes, rules, mesh)
