"""Typed configuration system.

Everything in the framework is driven by three dataclasses:

* :class:`ArchConfig` — one per backbone architecture (the 10 assigned archs +
  the paper's own DiT family live in ``repro.configs``).
* :class:`FlowRLConfig` — the paper's training configuration: which trainer,
  which SDE dynamics, which rewards, preprocessing on/off.
* :class:`RunConfig` — mesh / shapes / dtype / optimizer for a launch.

Configs are plain dataclasses so they can be loaded from dicts/JSON via
:func:`from_dict` (a native strict typed loader — no third-party dependency;
the paper uses YAML; the mechanism is identical).
"""
from __future__ import annotations

import dataclasses
import json
import typing
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio", "dit")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    # d_ff of each routed expert (dense d_ff field is used for dense layers)
    expert_d_ff: int = 0
    # first k layers stay dense (deepseek-v2 style)
    first_k_dense: int = 0
    # load-balance auxiliary loss coefficient
    aux_loss_coef: float = 0.01
    # router jitter / z-loss
    router_z_coef: float = 1e-3
    # sharding strategy: "tensor" (shard expert d_ff) | "expert" (all-to-all)
    sharding: str = "tensor"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block."""
    d_state: int = 64
    expand: int = 2            # d_inner = expand * d_model
    head_dim: int = 64         # SSD head dim (n_heads = d_inner // head_dim)
    chunk: int = 128           # chunked-scan block length
    d_conv: int = 4            # depthwise conv width


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid schedule: runs of SSM blocks with a periodically
    applied *shared* attention block (single parameter set reused)."""
    attn_every: int = 6        # one attn application per `attn_every` layers
    shared_attn: bool = True   # reuse one attention block's params


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend (assignment carve-out): provides precomputed
    patch/frame embeddings of the right shape; we implement the decoder."""
    kind: str = "none"         # none | vision | audio
    n_tokens: int = 0          # prefix length contributed by the frontend
    embed_dim: int = 0         # embedding dim delivered (projected to d_model)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attn-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # sliding-window attention; 0 = full causal. Enables long_500k for dense.
    window: int = 0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # citation of the source paper / model card for this config
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6·N·D roofline)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            per_layer = _ssm_layer_params(self)
        elif self.family == "hybrid":
            hy = self.hybrid or HybridConfig()
            n_attn_sites = self.n_layers // hy.attn_every
            attn_param_copies = 1 if hy.shared_attn else n_attn_sites
            attn = _attn_params(self, hd)
            total_layers = (self.n_layers * _ssm_layer_params(self)
                            + attn_param_copies * (attn + 3 * d * self.d_ff))
            return emb + total_layers + d  # + final norm
        else:
            attn = (_mla_params(self) if self.mla else _attn_params(self, hd))
            if self.moe and self.moe.n_experts:
                m = self.moe
                dense_layers = m.first_k_dense
                moe_layers = self.n_layers - dense_layers
                router = d * m.n_experts
                experts = (m.n_experts + m.n_shared_experts) * 3 * d * m.expert_d_ff
                ffn_moe = router + experts
                ffn_dense = 3 * d * self.d_ff
                return (emb + self.n_layers * (attn + 2 * d)
                        + moe_layers * ffn_moe + dense_layers * ffn_dense + d)
            per_layer = attn + 3 * d * self.d_ff + 2 * d
        return emb + self.n_layers * per_layer + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if not (self.moe and self.moe.n_experts):
            return self.n_params()
        d = self.d_model
        m = self.moe
        hd = self.resolved_head_dim
        attn = (_mla_params(self) if self.mla else _attn_params(self, hd))
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        moe_layers = self.n_layers - m.first_k_dense
        active_ffn = (m.top_k + m.n_shared_experts) * 3 * d * m.expert_d_ff \
            + d * m.n_experts
        return (emb + self.n_layers * (attn + 2 * d)
                + moe_layers * active_ffn
                + m.first_k_dense * 3 * d * self.d_ff + d)


def _attn_params(cfg: "ArchConfig", hd: int) -> int:
    d = cfg.d_model
    return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d


def _mla_params(cfg: "ArchConfig") -> int:
    m = cfg.mla
    d = cfg.d_model
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
            + d * (m.kv_lora_rank + m.qk_rope_head_dim)
            + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            + cfg.n_heads * m.v_head_dim * d)


def _ssm_layer_params(cfg: "ArchConfig") -> int:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    d_in = s.expand * d
    n_heads = d_in // s.head_dim
    # in_proj produces [z, x, B, C, dt]
    in_proj = d * (2 * d_in + 2 * s.d_state + n_heads)
    return in_proj + d_in * d + s.d_conv * (d_in + 2 * s.d_state) + 2 * n_heads + d


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Flow-RL (paper) config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RewardSpec:
    """One entry of the multi-reward configuration (paper §2.3)."""
    reward_type: str                  # registry name
    weight: float = 1.0
    # identifies the underlying frozen model; entries sharing model_id are
    # deduplicated by MultiRewardLoader
    model_id: str = ""
    args: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class FlowRLConfig:
    """The paper's training configuration — maps 1:1 onto its YAML schema."""
    trainer_type: str = "flow_grpo"      # flow_grpo | mix_grpo | grpo_guard | nft | awm
    sde_type: str = "flow_sde"           # flow_sde | dance_sde | cps | ode (Table 1)
    eta: float = 0.7                     # noise scale of the SDE dynamics
    num_steps: int = 10                  # denoising steps per trajectory
    group_size: int = 8                  # G samples per prompt (GRPO grouping)
    clip_range: float = 1e-4             # PPO clip range (log-ratio units, Flow-GRPO)
    kl_coef: float = 0.0
    advantage_agg: str = "weighted_sum"  # weighted_sum | gdpo
    rewards: Tuple[RewardSpec, ...] = ()
    # preprocessing-based memory optimization (paper §2.2)
    preprocessing: bool = True
    cache_dir: str = "cache"
    # timestep sampling for NFT/AWM (solver-agnostic algorithms, paper §3.2)
    timestep_sampling: str = "uniform"   # uniform | logit_normal | discrete
    # MixGRPO: how many leading timesteps get SDE treatment
    sde_window: int = 2
    sde_window_shift_every: int = 0      # >0: slide the window during training
    # latent geometry of the flow policy
    latent_tokens: int = 64
    latent_dim: int = 16


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 1e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.0
    warmup_steps: int = 10
    total_steps: int = 1000
    grad_clip: float = 1.0
    schedule: str = "warmup_cosine"      # warmup_cosine | constant
    optimizer: str = "adamw"             # registry name ("optimizer" kind)


@dataclass(frozen=True)
class MeshConfig:
    data: int = 1
    model: int = 1
    pods: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pods


@dataclass(frozen=True)
class ShardingConfig:
    # fsdp: additionally shard params over the data axis (zero-3)
    fsdp: bool = True
    # shard long decode KV caches over the data axis (distributed flash-decode)
    seq_shard_decode: bool = True
    # remat policy for train: "none" | "block" (checkpoint each layer block)
    remat: str = "block"


@dataclass(frozen=True)
class DistConfig:
    """Distributed training layout (``repro.distributed``): a 2-D
    ``("data", "model")`` device mesh.

    ``data_parallel``: device count on the mesh "data" axis — prompts×groups
    batches are sharded over it. 1 (default) is the single-device path (no
    mesh is built); 0 means "all local devices *not* claimed by
    model_parallel".  ``model_parallel``: device count on the "model" axis —
    params and AdamW moments are sharded over it per the ``PartitionPlan``
    (FSDP-style for dense backbone leaves, expert-parallel for MoE tables,
    head-parallel for attention/MLA projections); 1 (default) replicates
    params exactly as the historical 1-D path did, 0 means "all devices not
    claimed by data_parallel".  ``dp × mp`` is validated against
    ``jax.local_device_count()`` at mesh construction.  ``microbatch``:
    split each ``group_size × num_prompts`` batch into this many sequential
    gradient-accumulation chunks (0/1 = one full-batch pass).  These are
    runtime choices, not experiment identity: a checkpoint written at one
    layout resumes at any other (e.g. ``dp=4`` → ``dp=2×mp=2``) through the
    canonical unsharded on-disk layout."""
    data_parallel: int = 1
    model_parallel: int = 1
    microbatch: int = 0
    # donate the RLState buffers to the jitted update (params + AdamW
    # moments rewritten in place instead of double-buffered)
    donate_state: bool = True


@dataclass(frozen=True)
class PerfConfig:
    """Train-step performance policy (``repro.perf``).

    Like :class:`DistConfig` this is a *runtime* choice, not experiment
    identity: checkpoints written under one perf policy resume under any
    other.  ``remat``: activation rematerialization for the RL hot loop —
    ``"none"`` stores full backbone activations for every denoising step of
    the loss scan; ``"scan"`` wraps the rollout/loss scan bodies in
    ``jax.checkpoint`` (bit-identical losses/gradients on XLA:CPU — the
    scan backward structurally isolates the body, so the recompute graph
    matches); ``"block"`` additionally checkpoints each backbone layer
    block inside the velocity forward (f32-rounding-equal only: XLA
    re-fuses the open-graph remat).  ``fuse_step``: compile
    sample→rewards→advantages→update into ONE donated jit (step metrics
    computed on device inside it) instead of three host-dispatched jits.
    ``policy_dtype``: explicit activation compute dtype for the velocity
    field ("" = the parameter dtype, today's behaviour; log-probabilities
    and the optimizer always stay float32).  ``log_memory``: compile the
    update ahead of time and report ``memory_analysis()`` byte counts.
    ``offload_rewards``: park the frozen reward-tower params in host
    memory and thread them into the rewards/fused jit as *arguments*
    (H2D prefetched by the TrainLoop while the previous step's backward
    runs) instead of keeping them device-resident as trace-time
    constants — frees their device bytes, f32-rounding-equal (a
    different compiled program).  ``remat_offload``: under
    ``remat="scan"``, offload the scan body's saved velocity residual
    to host memory via ``jax.checkpoint_policies
    .save_and_offload_only_these_names`` instead of recomputing it."""
    remat: str = "none"            # none | scan | block
    fuse_step: bool = False
    policy_dtype: str = ""         # "" | "bfloat16" | "float32"
    log_memory: bool = False
    offload_rewards: bool = False
    remat_offload: bool = False    # requires remat="scan"


@dataclass(frozen=True)
class DataConfig:
    """Prompt-dataset + frozen-encoder selection for an Experiment."""
    dataset: str = "synthetic"           # registry name ("dataset" kind)
    n_prompts: int = 64
    batch_prompts: int = 4
    # extra kwargs forwarded to the registered dataset factory
    args: Dict[str, Any] = field(default_factory=dict)
    # kwargs of the frozen condition encoder (cond_dim/cond_len/vocab/...);
    # empty -> FrozenTextEncoder defaults (the paper-scale ~67M tower)
    encoder: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class LoopConfig:
    """TrainLoop behaviour: length, logging, checkpointing, early stop.

    ``pipeline``: max train steps in flight before the loop drains metrics
    (1 = today's fully sequential loop, bit-identical; K>1 overlaps the
    host-side work of step N+1..N+K-1 with step N's device execution —
    metrics are observed up to K-1 steps late, but *what* is computed
    never changes; see ``repro.api.loop``)."""
    steps: int = 100
    pipeline: int = 1                    # max dispatched-not-drained steps
    log_every: int = 10                  # 0 -> silent
    save_every: int = 50                 # 0 -> no periodic checkpoints
    ckpt_dir: str = "checkpoints"
    log_file: str = ""                   # non-empty -> JSON metric sink
    # rewrite the JSON metric log every N steps (crash-safety window); the
    # sink rewrites the whole history each flush, so long runs should
    # raise this to bound cumulative IO
    log_flush_every: int = 1
    resume: bool = True                  # auto-resume from latest checkpoint
    early_stop_patience: int = 0         # 0 -> disabled
    early_stop_metric: str = "reward"    # any TrainLoop history-row key
    early_stop_min_delta: float = 0.0


@dataclass(frozen=True)
class RunConfig:
    arch: str = "smollm-360m"
    # use the ≤2-layer reduced arch variant (CPU-runnable smoke scale)
    reduced: bool = False
    # declarative field overrides applied onto the resolved ArchConfig
    # (e.g. {"n_layers": 12, "d_model": 768} for a custom DiT size)
    arch_overrides: Dict[str, Any] = field(default_factory=dict)
    shape: str = "train_4k"
    mesh: MeshConfig = field(default_factory=MeshConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    optim: OptimConfig = field(default_factory=OptimConfig)
    flow: FlowRLConfig = field(default_factory=FlowRLConfig)
    dist: DistConfig = field(default_factory=DistConfig)
    perf: PerfConfig = field(default_factory=PerfConfig)
    data: DataConfig = field(default_factory=DataConfig)
    loop: LoopConfig = field(default_factory=LoopConfig)
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"
    seed: int = 0


# ---------------------------------------------------------------------------
# Loading — native strict typed from_dict (nested dataclasses, tuples,
# Optional, Dict/List, unknown-key errors). No third-party dependency.
# ---------------------------------------------------------------------------


class ConfigError(TypeError):
    """Raised when a dict doesn't match the target dataclass schema."""


def _type_name(tp: Any) -> str:
    return getattr(tp, "__name__", None) or str(tp)


def coerce(value: Any, tp: Any, path: str = "<value>") -> Any:
    """Convert ``value`` to type ``tp`` (typing construct or dataclass),
    raising :class:`ConfigError` with the dotted ``path`` on mismatch."""
    if tp is Any or tp is dataclasses.MISSING:
        return value
    origin = typing.get_origin(tp)
    args = typing.get_args(tp)
    if origin is typing.Union:                      # Optional[T] / Union
        if value is None and type(None) in args:
            return None
        errors = []
        for cand in args:
            if cand is type(None):
                continue
            try:
                return coerce(value, cand, path)
            except ConfigError as e:
                errors.append(str(e))
        raise ConfigError(f"{path}: {value!r} matches no member of "
                          f"{_type_name(tp)} ({'; '.join(errors)})")
    if dataclasses.is_dataclass(tp) and isinstance(tp, type):
        if isinstance(value, tp):
            return value
        if not isinstance(value, dict):
            raise ConfigError(f"{path}: expected a dict for "
                              f"{_type_name(tp)}, got {type(value).__name__}")
        return from_dict(tp, value, _path=path)
    if origin in (tuple,) or tp is tuple:
        if not isinstance(value, (list, tuple)):
            raise ConfigError(f"{path}: expected a sequence, got "
                              f"{type(value).__name__}")
        if not args:                                 # bare tuple
            return tuple(value)
        if len(args) == 2 and args[1] is Ellipsis:   # Tuple[T, ...]
            return tuple(coerce(v, args[0], f"{path}[{i}]")
                         for i, v in enumerate(value))
        if len(value) != len(args):                  # Tuple[T1, T2, ...]
            raise ConfigError(f"{path}: expected {len(args)} items, "
                              f"got {len(value)}")
        return tuple(coerce(v, a, f"{path}[{i}]")
                     for i, (v, a) in enumerate(zip(value, args)))
    if origin in (list,) or tp is list:
        if not isinstance(value, (list, tuple)):
            raise ConfigError(f"{path}: expected a list, got "
                              f"{type(value).__name__}")
        elem = args[0] if args else Any
        return [coerce(v, elem, f"{path}[{i}]") for i, v in enumerate(value)]
    if origin in (dict,) or tp is dict:
        if not isinstance(value, dict):
            raise ConfigError(f"{path}: expected a dict, got "
                              f"{type(value).__name__}")
        kt, vt = args if args else (Any, Any)
        return {coerce(k, kt, f"{path}<key>"): coerce(v, vt, f"{path}[{k}]")
                for k, v in value.items()}
    if tp is bool:
        if isinstance(value, bool):
            return value
        raise ConfigError(f"{path}: expected bool, got {value!r}")
    if tp is int:
        if isinstance(value, int) and not isinstance(value, bool):
            return value
        raise ConfigError(f"{path}: expected int, got {value!r}")
    if tp is float:
        if isinstance(value, (int, float)) and not isinstance(value, bool):
            return float(value)
        raise ConfigError(f"{path}: expected float, got {value!r}")
    if tp is str:
        if isinstance(value, str):
            return value
        raise ConfigError(f"{path}: expected str, got {value!r}")
    if isinstance(tp, type):
        if isinstance(value, tp):
            return value
        raise ConfigError(f"{path}: expected {_type_name(tp)}, got "
                          f"{type(value).__name__}")
    return value


def field_types(cls: type) -> Dict[str, Any]:
    """Resolved {field name: type} for a dataclass (PEP 563 safe)."""
    return typing.get_type_hints(cls)


def from_dict(cls: type, d: Dict[str, Any], *, _path: str = "") -> Any:
    """Strict typed construction of dataclass ``cls`` from a plain dict.

    Handles nested dataclasses, ``Tuple``/``List``/``Dict``/``Optional``
    fields, casts lists to tuples, and raises :class:`ConfigError` on
    unknown keys or type mismatches (with the dotted field path)."""
    if not dataclasses.is_dataclass(cls):
        raise ConfigError(f"{cls!r} is not a dataclass")
    if not isinstance(d, dict):
        raise ConfigError(f"{_path or _type_name(cls)}: expected a dict, "
                          f"got {type(d).__name__}")
    names = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - names)
    if unknown:
        raise ConfigError(
            f"{_path or _type_name(cls)}: unknown key(s) {unknown} for "
            f"{_type_name(cls)}; valid keys: {sorted(names)}")
    hints = field_types(cls)
    kwargs = {k: coerce(v, hints[k], f"{_path}.{k}" if _path else k)
              for k, v in d.items()}
    try:
        return cls(**kwargs)
    except TypeError as e:                # e.g. missing required field
        raise ConfigError(f"{_path or _type_name(cls)}: {e}") from None


def load_json(cls: type, path: str) -> Any:
    with open(path) as f:
        return from_dict(cls, json.load(f))


def to_dict(cfg: Any) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def replace(cfg: Any, **kw: Any) -> Any:
    return dataclasses.replace(cfg, **kw)
