"""Typed configuration system.

Everything in the framework is driven by three dataclasses:

* :class:`ArchConfig` — one per backbone architecture (the 10 assigned archs +
  the paper's own DiT family live in ``repro.configs``).
* :class:`FlowRLConfig` — the paper's training configuration: which trainer,
  which SDE dynamics, which rewards, preprocessing on/off.
* :class:`RunConfig` — mesh / shapes / dtype / optimizer for a launch.

Configs are plain dataclasses so they can be loaded from dicts/JSON via
:func:`from_dict` (dacite) — the paper uses YAML; the mechanism is identical.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import dacite

# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio", "dit")


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    # d_ff of each routed expert (dense d_ff field is used for dense layers)
    expert_d_ff: int = 0
    # first k layers stay dense (deepseek-v2 style)
    first_k_dense: int = 0
    # load-balance auxiliary loss coefficient
    aux_loss_coef: float = 0.01
    # router jitter / z-loss
    router_z_coef: float = 1e-3
    # sharding strategy: "tensor" (shard expert d_ff) | "expert" (all-to-all)
    sharding: str = "tensor"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 SSD block."""
    d_state: int = 64
    expand: int = 2            # d_inner = expand * d_model
    head_dim: int = 64         # SSD head dim (n_heads = d_inner // head_dim)
    chunk: int = 128           # chunked-scan block length
    d_conv: int = 4            # depthwise conv width


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid schedule: runs of SSM blocks with a periodically
    applied *shared* attention block (single parameter set reused)."""
    attn_every: int = 6        # one attn application per `attn_every` layers
    shared_attn: bool = True   # reuse one attention block's params


@dataclass(frozen=True)
class FrontendConfig:
    """Stub modality frontend (assignment carve-out): provides precomputed
    patch/frame embeddings of the right shape; we implement the decoder."""
    kind: str = "none"         # none | vision | audio
    n_tokens: int = 0          # prefix length contributed by the frontend
    embed_dim: int = 0         # embedding dim delivered (projected to d_model)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # one of FAMILIES
    n_layers: int
    d_model: int
    n_heads: int                      # 0 for attn-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # sliding-window attention; 0 = full causal. Enables long_500k for dense.
    window: int = 0
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: FrontendConfig = field(default_factory=FrontendConfig)
    # citation of the source paper / model card for this config
    source: str = ""

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    def n_params(self) -> int:
        """Total parameter count (used for MODEL_FLOPS = 6·N·D roofline)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family == "ssm":
            per_layer = _ssm_layer_params(self)
        elif self.family == "hybrid":
            hy = self.hybrid or HybridConfig()
            n_attn_sites = self.n_layers // hy.attn_every
            attn_param_copies = 1 if hy.shared_attn else n_attn_sites
            attn = _attn_params(self, hd)
            total_layers = (self.n_layers * _ssm_layer_params(self)
                            + attn_param_copies * (attn + 3 * d * self.d_ff))
            return emb + total_layers + d  # + final norm
        else:
            attn = (_mla_params(self) if self.mla else _attn_params(self, hd))
            if self.moe and self.moe.n_experts:
                m = self.moe
                dense_layers = m.first_k_dense
                moe_layers = self.n_layers - dense_layers
                router = d * m.n_experts
                experts = (m.n_experts + m.n_shared_experts) * 3 * d * m.expert_d_ff
                ffn_moe = router + experts
                ffn_dense = 3 * d * self.d_ff
                return (emb + self.n_layers * (attn + 2 * d)
                        + moe_layers * ffn_moe + dense_layers * ffn_dense + d)
            per_layer = attn + 3 * d * self.d_ff + 2 * d
        return emb + self.n_layers * per_layer + d

    def n_active_params(self) -> int:
        """Active params per token (MoE: only top_k + shared experts)."""
        if not (self.moe and self.moe.n_experts):
            return self.n_params()
        d = self.d_model
        m = self.moe
        hd = self.resolved_head_dim
        attn = (_mla_params(self) if self.mla else _attn_params(self, hd))
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        moe_layers = self.n_layers - m.first_k_dense
        active_ffn = (m.top_k + m.n_shared_experts) * 3 * d * m.expert_d_ff \
            + d * m.n_experts
        return (emb + self.n_layers * (attn + 2 * d)
                + moe_layers * active_ffn
                + m.first_k_dense * 3 * d * self.d_ff + d)


def _attn_params(cfg: "ArchConfig", hd: int) -> int:
    d = cfg.d_model
    return d * cfg.n_heads * hd + 2 * d * cfg.n_kv_heads * hd + cfg.n_heads * hd * d


def _mla_params(cfg: "ArchConfig") -> int:
    m = cfg.mla
    d = cfg.d_model
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qk
            + d * (m.kv_lora_rank + m.qk_rope_head_dim)
            + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            + cfg.n_heads * m.v_head_dim * d)


def _ssm_layer_params(cfg: "ArchConfig") -> int:
    s = cfg.ssm or SSMConfig()
    d = cfg.d_model
    d_in = s.expand * d
    n_heads = d_in // s.head_dim
    # in_proj produces [z, x, B, C, dt]
    in_proj = d * (2 * d_in + 2 * s.d_state + n_heads)
    return in_proj + d_in * d + s.d_conv * (d_in + 2 * s.d_state) + 2 * n_heads + d


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Flow-RL (paper) config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RewardSpec:
    """One entry of the multi-reward configuration (paper §2.3)."""
    reward_type: str                  # registry name
    weight: float = 1.0
    # identifies the underlying frozen model; entries sharing model_id are
    # deduplicated by MultiRewardLoader
    model_id: str = ""
    args: Dict[str, Any] = field(default_factory=dict)


@dataclass(frozen=True)
class FlowRLConfig:
    """The paper's training configuration — maps 1:1 onto its YAML schema."""
    trainer_type: str = "flow_grpo"      # flow_grpo | mix_grpo | grpo_guard | nft | awm
    sde_type: str = "flow_sde"           # flow_sde | dance_sde | cps | ode (Table 1)
    eta: float = 0.7                     # noise scale of the SDE dynamics
    num_steps: int = 10                  # denoising steps per trajectory
    group_size: int = 8                  # G samples per prompt (GRPO grouping)
    clip_range: float = 1e-4             # PPO clip range (log-ratio units, Flow-GRPO)
    kl_coef: float = 0.0
    advantage_agg: str = "weighted_sum"  # weighted_sum | gdpo
    rewards: Tuple[RewardSpec, ...] = ()
    # preprocessing-based memory optimization (paper §2.2)
    preprocessing: bool = True
    cache_dir: str = "cache"
    # timestep sampling for NFT/AWM (solver-agnostic algorithms, paper §3.2)
    timestep_sampling: str = "uniform"   # uniform | logit_normal | discrete
    # MixGRPO: how many leading timesteps get SDE treatment
    sde_window: int = 2
    sde_window_shift_every: int = 0      # >0: slide the window during training
    # latent geometry of the flow policy
    latent_tokens: int = 64
    latent_dim: int = 16


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 1e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.0
    warmup_steps: int = 10
    total_steps: int = 1000
    grad_clip: float = 1.0
    schedule: str = "warmup_cosine"      # warmup_cosine | constant


@dataclass(frozen=True)
class MeshConfig:
    data: int = 1
    model: int = 1
    pods: int = 1

    @property
    def n_devices(self) -> int:
        return self.data * self.model * self.pods


@dataclass(frozen=True)
class ShardingConfig:
    # fsdp: additionally shard params over the data axis (zero-3)
    fsdp: bool = True
    # shard long decode KV caches over the data axis (distributed flash-decode)
    seq_shard_decode: bool = True
    # remat policy for train: "none" | "block" (checkpoint each layer block)
    remat: str = "block"


@dataclass(frozen=True)
class RunConfig:
    arch: str = "smollm-360m"
    shape: str = "train_4k"
    mesh: MeshConfig = field(default_factory=MeshConfig)
    sharding: ShardingConfig = field(default_factory=ShardingConfig)
    optim: OptimConfig = field(default_factory=OptimConfig)
    flow: FlowRLConfig = field(default_factory=FlowRLConfig)
    param_dtype: str = "bfloat16"
    activ_dtype: str = "bfloat16"
    seed: int = 0


# ---------------------------------------------------------------------------
# Loading
# ---------------------------------------------------------------------------

_DACITE_CFG = dacite.Config(cast=[tuple], strict=True)


def from_dict(cls: type, d: Dict[str, Any]) -> Any:
    return dacite.from_dict(data_class=cls, data=d, config=_DACITE_CFG)


def load_json(cls: type, path: str) -> Any:
    with open(path) as f:
        return from_dict(cls, json.load(f))


def to_dict(cfg: Any) -> Dict[str, Any]:
    return dataclasses.asdict(cfg)


def replace(cfg: Any, **kw: Any) -> Any:
    return dataclasses.replace(cfg, **kw)
