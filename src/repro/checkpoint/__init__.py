from repro.checkpoint.io import (save_checkpoint, load_checkpoint,
                                 latest_step, restore_latest)

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step",
           "restore_latest"]
