"""Pytree checkpointing: npz payload + msgpack/JSON manifest (no orbax on
image; the manifest falls back to JSON when msgpack is unavailable).

Multi-host aware: arrays are gathered to host (``jax.device_get``) before
writing; on restore, the caller re-shards by donating the loaded tree into a
jit'd identity with the desired shardings (see launch/train.py).  Writes are
atomic (tmp + rename) so a preempted save never corrupts the latest step.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

try:
    import msgpack
except ImportError:                      # pragma: no cover - env dependent
    msgpack = None                       # gate: JSON manifests instead

_SEP = "/"


def _pack_manifest(manifest: dict) -> bytes:
    if msgpack is not None:
        return msgpack.packb(manifest)
    return json.dumps(manifest).encode()


def _unpack_manifest(raw: bytes) -> dict:
    # JSON manifests start with '{'; msgpack fixmaps never do
    if raw[:1] == b"{":
        return json.loads(raw.decode())
    if msgpack is None:
        raise RuntimeError("checkpoint manifest is msgpack-encoded but the "
                           "'msgpack' module is not installed")
    return msgpack.unpackb(raw)


def _path_entry(k) -> str:
    # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (NamedTuples such as
    # RLState/AdamWState) -> .name
    for attr in ("key", "idx", "name"):
        v = getattr(k, attr, None)
        if v is not None:
            return str(v)
    return str(k)


def _flatten_with_paths(tree) -> Tuple[list, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_entry(k) for k in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "keys": [], "dtypes": {}}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        manifest["keys"].append(key)
        manifest["dtypes"][key] = str(arr.dtype)
        # bf16 isn't npz-native: store as uint16 view, restore via manifest
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[key] = arr
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    # manifest lands (atomically) BEFORE the npz: latest_step() keys on the
    # .npz, so a preemption between the two leaves at worst an orphan
    # manifest that the next save overwrites — never a discoverable
    # checkpoint that crashes restore for want of its manifest
    tmp_m = path + ".tmp.manifest"
    with open(tmp_m, "wb") as f:
        f.write(_pack_manifest(manifest))
    os.replace(tmp_m, path + ".manifest")
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path + ".npz")
    return path + ".npz"


def load_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(path + ".manifest", "rb") as f:
        manifest = _unpack_manifest(f.read())
    flat, treedef = _flatten_with_paths(like)
    with np.load(path + ".npz") as z:
        missing = [key for key, _ in flat if key not in z.files]
        if missing:
            raise ValueError(
                f"checkpoint {path}.npz doesn't match the requested "
                f"structure: {len(missing)} missing key(s), e.g. "
                f"{missing[:3]} — was it saved from a different state "
                "layout (legacy params-only checkpoint restored as a full "
                "RLState)?")
        leaves = []
        for key, leaf in flat:
            arr = z[key]
            want = manifest["dtypes"][key]
            if want == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_latest(ckpt_dir: str, like: Any) -> Tuple[Optional[int], Any]:
    """Restore the newest checkpoint into the structure of ``like``.

    Returns ``(step, tree)``; ``(None, like)`` when no checkpoint exists.
    ``like`` may be any pytree — in particular a trainer's full ``RLState``
    (params **and** optimizer moments), which is what the Experiment layer
    saves, so a resumed run is bit-identical to an uninterrupted one."""
    step = latest_step(ckpt_dir)
    if step is None:
        return None, like
    return step, load_checkpoint(ckpt_dir, step, like)
