"""Pytree checkpointing: npz payload + msgpack manifest (no orbax on image).

Multi-host aware: arrays are gathered to host (``jax.device_get``) before
writing; on restore, the caller re-shards by donating the loaded tree into a
jit'd identity with the desired shardings (see launch/train.py).  Writes are
atomic (tmp + rename) so a preempted save never corrupts the latest step.
"""
from __future__ import annotations

import os
import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

_SEP = "/"


def _flatten_with_paths(tree) -> Tuple[list, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "keys": [], "dtypes": {}}
    for key, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        manifest["keys"].append(key)
        manifest["dtypes"][key] = str(arr.dtype)
        # bf16 isn't npz-native: store as uint16 view, restore via manifest
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        arrays[key] = arr
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path + ".npz")
    with open(path + ".manifest", "wb") as f:
        f.write(msgpack.packb(manifest))
    return path + ".npz"


def load_checkpoint(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype validated)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(path + ".manifest", "rb") as f:
        manifest = msgpack.unpackb(f.read())
    flat, treedef = _flatten_with_paths(like)
    with np.load(path + ".npz") as z:
        leaves = []
        for key, leaf in flat:
            arr = z[key]
            want = manifest["dtypes"][key]
            if want == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"step_(\d+)\.npz$", f))]
    return max(steps) if steps else None
