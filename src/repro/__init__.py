"""Flow-Factory-JAX: unified RL for flow-matching models (+ the assigned
10-architecture backbone zoo) on multi-pod TPU meshes.

NOTE: importing ``repro`` must NOT initialize jax (the dry-run sets
XLA_FLAGS *after* package import, before first jax use) — component
registration is therefore lazy: the registry autoloads the registering
modules on the first lookup miss (see repro.registry)."""
from repro import registry  # noqa: F401

__version__ = "1.0.0"
