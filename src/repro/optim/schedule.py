"""Learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import OptimConfig

F32 = jnp.float32


def make_schedule(cfg: OptimConfig):
    """Returns lr(step) -> scalar f32."""
    if cfg.schedule == "constant":
        return lambda step: jnp.asarray(cfg.lr, F32)

    if cfg.schedule == "warmup_cosine":
        def lr(step):
            s = step.astype(F32) if hasattr(step, "astype") else float(step)
            s = s + 1.0            # step counter is 0-based; never emit lr=0
            warm = jnp.minimum(s / max(cfg.warmup_steps, 1), 1.0)
            prog = jnp.clip((s - cfg.warmup_steps)
                            / max(cfg.total_steps - cfg.warmup_steps, 1),
                            0.0, 1.0)
            cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
            return cfg.lr * warm * (0.1 + 0.9 * cos)
        return lr

    raise ValueError(f"unknown schedule {cfg.schedule}")
