"""AdamW over pytrees (no optax on the image — built from scratch).

Memory policy: moments in f32 regardless of param dtype (bf16 params,
f32 state — the standard mixed-precision training layout); the update is
computed in f32 and cast back to the param dtype.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimConfig

F32 = jnp.float32


class AdamWState(NamedTuple):
    step: jax.Array     # () int32
    mu: dict            # first moments (f32, same tree as params)
    nu: dict            # second moments (f32)


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: AdamWState, cfg: OptimConfig,
                 lr: jax.Array) -> Tuple[dict, AdamWState]:
    b1, b2 = cfg.betas
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(F32)
    c2 = 1.0 - b2 ** step.astype(F32)

    def upd(p, g, m, v):
        gf = g.astype(F32)
        m2 = b1 * m + (1 - b1) * gf
        v2 = b2 * v + (1 - b2) * gf * gf
        mhat = m2 / c1
        vhat = v2 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
