from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import make_schedule
from repro.optim.clip import global_norm, clip_by_global_norm

__all__ = ["AdamWState", "adamw_init", "adamw_update", "make_schedule",
           "global_norm", "clip_by_global_norm"]
