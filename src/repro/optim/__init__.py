from repro import registry
from repro.optim.adamw import AdamWState, adamw_init, adamw_update
from repro.optim.schedule import make_schedule
from repro.optim.clip import global_norm, clip_by_global_norm


@registry.register("optimizer", "adamw")
class AdamW:
    """Registry front for the from-scratch AdamW (init/update pair);
    selected via ``OptimConfig.optimizer`` so alternative optimizers plug
    in without touching any trainer."""
    init = staticmethod(adamw_init)
    update = staticmethod(adamw_update)


__all__ = ["AdamWState", "adamw_init", "adamw_update", "make_schedule",
           "global_norm", "clip_by_global_norm", "AdamW"]
