"""Gradient clipping utilities."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    sq = sum(jnp.sum(l.astype(F32) ** 2) for l in leaves)
    return jnp.sqrt(sq)


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(F32) * scale).astype(g.dtype),
                        tree), gn
