"""Prompt dataset for RL fine-tuning (the paper trains on text prompts).

``synthetic_prompts`` generates a deterministic compositional prompt corpus
(the Pick-a-Pic/OCR-style distribution stand-in); ``PromptDataset`` provides
shuffled epoch iteration with per-host sharding for multi-process launches.
The corpus is registered as ``dataset:synthetic`` so Experiments resolve it
from configuration alone.
"""
from __future__ import annotations

import itertools
from typing import Iterator, List, Sequence

import numpy as np

from repro import registry

_SUBJECTS = ["a fox", "an astronaut", "a teapot", "two dancers", "a robot",
             "a lighthouse", "an origami crane", "a neon sign", "a tram",
             "a violin"]
_STYLES = ["in watercolor", "as pixel art", "in film noir lighting",
           "as a blueprint", "in ukiyo-e style", "as claymation",
           "in double exposure", "as stained glass"]
_TEXTS = ["with the word 'flow' painted on it", "holding a sign saying 'RL'",
          "next to graffiti reading 'factory'", "at golden hour",
          "under a thunderstorm", ""]


def synthetic_prompts(n: int, seed: int = 0) -> List[str]:
    rng = np.random.RandomState(seed)
    combos = list(itertools.product(_SUBJECTS, _STYLES, _TEXTS))
    idx = rng.permutation(len(combos))
    out = []
    for i in range(n):
        s, st, tx = combos[idx[i % len(combos)]]
        out.append(" ".join(w for w in (s, st, tx) if w))
    return out


class PromptDataset:
    def __init__(self, prompts: Sequence[str], batch_size: int, *,
                 seed: int = 0, host_id: int = 0, n_hosts: int = 1):
        self.prompts = list(prompts)[host_id::n_hosts]
        self.batch_size = batch_size
        self.seed = seed

    def __len__(self) -> int:
        return len(self.prompts)

    @property
    def batches_per_epoch(self) -> int:
        n = len(self.prompts)
        if n < self.batch_size:
            return 0
        return (n - self.batch_size) // self.batch_size + 1

    def epoch(self, epoch_idx: int, start_batch: int = 0
              ) -> Iterator[List[str]]:
        rng = np.random.RandomState(self.seed + epoch_idx)
        order = rng.permutation(len(self.prompts))
        for i in range(start_batch * self.batch_size,
                       len(order) - self.batch_size + 1, self.batch_size):
            yield [self.prompts[j] for j in order[i:i + self.batch_size]]

    def infinite(self, skip: int = 0) -> Iterator[List[str]]:
        """Endless shuffled batches; ``skip`` fast-forwards past the first
        ``skip`` batches in O(1) (each epoch's permutation is a pure
        function of ``seed + epoch_idx``, so resuming at batch N needs no
        replay — the TrainLoop's resume path relies on the skipped and
        replayed streams being identical)."""
        if skip < 0:
            raise ValueError(f"skip must be >= 0, got {skip}")
        per = self.batches_per_epoch
        if per == 0:
            raise ValueError(
                f"dataset of {len(self.prompts)} prompts yields no batch of "
                f"size {self.batch_size} — nothing to iterate")
        e0, off = divmod(skip, per)
        for e in itertools.count(e0):
            yield from self.epoch(e, start_batch=off if e == e0 else 0)


@registry.register("dataset", "synthetic")
def synthetic_dataset(n_prompts: int = 64, batch_prompts: int = 4,
                      seed: int = 0, host_id: int = 0,
                      n_hosts: int = 1) -> PromptDataset:
    """Deterministic compositional prompt corpus wrapped in a PromptDataset
    (the framework's config-addressable default training distribution)."""
    return PromptDataset(synthetic_prompts(n_prompts, seed=seed),
                         batch_size=batch_prompts, seed=seed,
                         host_id=host_id, n_hosts=n_hosts)
