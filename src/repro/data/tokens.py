"""Token stream for the LM-role training path (synthetic corpus with
learnable structure — a hash-ngram Markov source, so CE decreases and tests
can assert learning, unlike uniform-random tokens)."""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class TokenStream:
    def __init__(self, vocab_size: int, batch: int, seq: int, *,
                 seed: int = 0, order: int = 2):
        self.vocab, self.batch, self.seq = vocab_size, batch, seq
        self.order = order
        self.rng = np.random.RandomState(seed)
        # deterministic sparse transition structure
        self._mix = self.rng.randint(1, vocab_size, size=(order,))

    def _next_token(self, ctx: np.ndarray, noise: np.ndarray) -> np.ndarray:
        det = (ctx * self._mix[None]).sum(-1) % self.vocab
        return np.where(noise < 0.8, det, self.rng.randint(
            0, self.vocab, size=det.shape))

    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            toks = np.zeros((self.batch, self.seq + 1), np.int32)
            toks[:, :self.order] = self.rng.randint(
                0, self.vocab, size=(self.batch, self.order))
            for i in range(self.order, self.seq + 1):
                noise = self.rng.rand(self.batch)
                toks[:, i] = self._next_token(
                    toks[:, i - self.order:i], noise)
            yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
