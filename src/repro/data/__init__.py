from repro.data.prompts import PromptDataset, synthetic_prompts
from repro.data.tokens import TokenStream

__all__ = ["PromptDataset", "synthetic_prompts", "TokenStream"]
