"""``shard_map`` entry point: per-device independent rollouts.

The jit-with-sharding path (``sharding.jit_sample``) keeps multi-device
sampling numerically identical to single-device — the right tool for
training.  For pure *generation throughput* (filling a reward buffer,
serving bursts) cross-layout bit-equality is irrelevant; this entry point
instead hands each data shard its own fold of the PRNG key and runs the
rollout fully locally — zero cross-device communication, embarrassingly
parallel.  Consequently the samples differ from (are statistically
exchangeable with, not equal to) a single-device rollout of the same key.

On a 2-D ``(data, model)`` mesh the shard_map paths mention only the
"data" axis: params arrive replicated (gathered) and every model column
computes the same shard — correct, but it forgoes the PartitionPlan's
memory win.  The serving executor (``make_rollout_keyed_sharded``)
therefore switches to a plan-consuming SPMD jit when ``mp > 1``.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.rollout import Trajectory, rollout, rollout_keyed
from repro.distributed.mesh import DATA_AXIS, mesh_dp, mesh_mp
from repro.distributed.sharding import (batch_sharding, replicated,
                                        traj_shardings)


def make_rollout_sharded(adapter, scheduler, num_steps: int, mesh: Mesh,
                         sde_mask=None):
    """Build the jitted per-shard rollout ONCE; returns
    ``fn(params, cond, key) -> Trajectory``.  Reuse the returned callable
    across calls (a generation loop) — rebuilding it per batch re-traces
    the whole rollout every time."""

    def local(params, cond_shard, key):
        k = jax.random.fold_in(key, jax.lax.axis_index(DATA_AXIS))
        return rollout(adapter, params, cond_shard, k, scheduler, num_steps,
                       sde_mask)

    out_specs = Trajectory(xs=P(None, DATA_AXIS), logps=P(None, DATA_AXIS),
                           ts=P(), sde_mask=P(), cond=P(DATA_AXIS))
    # check_rep=False: ts/sde_mask are replicated by construction (identical
    # computation per shard) but shard_map cannot prove it
    sharded = shard_map(local, mesh=mesh, in_specs=(P(), P(DATA_AXIS), P()),
                        out_specs=out_specs, check_rep=False)
    dp = mesh_dp(mesh)

    def run(params, cond: jax.Array, key: jax.Array) -> Trajectory:
        if cond.shape[0] % dp != 0:
            raise ValueError(
                f"rollout batch {cond.shape[0]} is not divisible by the "
                f"data axis ({dp} devices)")
        return _jitted(params, cond, key)

    _jitted = jax.jit(sharded)
    return run


def make_rollout_keyed_sharded(adapter, scheduler, num_steps: int,
                               mesh: Optional[Mesh], x0_only: bool = False,
                               plan=None):
    """Sharded entry point for the *per-request-keyed* rollout (the serving
    engine's executor): cond AND the (B, 2) per-request key batch are both
    sharded over the data axis.

    On a data-only mesh (``mp=1``) this is a ``shard_map``: each device
    runs exactly the computation the single-device path runs for its slice
    of requests — no axis-index key folding, hence **bit-identical per
    request** to ``mesh=None`` (tests/test_serving.py asserts exact
    equality on 4 faked host devices).  With ``mp > 1`` the executor is
    instead an SPMD jit consuming the PartitionPlan — params stay
    model-sharded (the memory point of the plan) and XLA inserts the
    gather collectives, so results are f32-rounding-equal (reduction
    order), not bit-identical, to the ``mp=1`` layouts.

    Returns ``fn(params, cond, keys, sde_mask) -> Trajectory`` (jitted;
    build once per (batch, num_steps) shape and reuse — the engine's
    compile cache does exactly that).  Batch must divide the mesh's data
    axis; the engine's bucket grid is dp-aligned to guarantee it.

    ``x0_only=True`` returns just the final latents (B, Lt, ld) — the
    serving queue's executor: XLA then dead-code-eliminates the stacked
    per-step trajectory/log-prob buffers the scan would otherwise
    materialize (x0 values are bit-identical either way)."""

    def local(params, cond_shard, keys_shard, sde_mask):
        traj = rollout_keyed(adapter, params, cond_shard, keys_shard,
                             scheduler, num_steps, sde_mask)
        return traj.x0 if x0_only else traj

    if mesh is None:
        return jax.jit(local)
    dp = mesh_dp(mesh)
    if mesh_mp(mesh) > 1:
        rep = replicated(mesh)
        psh = plan.param_shardings() if plan is not None else rep
        b0 = batch_sharding(mesh, 0)
        out_sh = b0 if x0_only else traj_shardings(mesh)
        _jitted = jax.jit(local, in_shardings=(psh, b0, b0, rep),
                          out_shardings=out_sh)
    else:
        out_specs = (P(DATA_AXIS) if x0_only else
                     Trajectory(xs=P(None, DATA_AXIS),
                                logps=P(None, DATA_AXIS),
                                ts=P(), sde_mask=P(), cond=P(DATA_AXIS)))
        # check_rep=False: ts/sde_mask are replicated by construction
        # (identical computation per shard) but shard_map cannot prove it
        sharded = shard_map(local, mesh=mesh,
                            in_specs=(P(), P(DATA_AXIS), P(DATA_AXIS), P()),
                            out_specs=out_specs, check_rep=False)
        _jitted = jax.jit(sharded)

    def run(params, cond, keys, sde_mask):
        if cond.shape[0] % dp != 0:
            raise ValueError(
                f"keyed rollout batch {cond.shape[0]} is not divisible by "
                f"the data axis ({dp} devices) — bucket sizes must be "
                "dp-aligned")
        return _jitted(params, cond, keys, sde_mask)

    return run


def rollout_sharded(adapter, params, cond: jax.Array, key: jax.Array,
                    scheduler, num_steps: int, mesh: Optional[Mesh],
                    sde_mask=None) -> Trajectory:
    """One-shot convenience over ``make_rollout_sharded`` (falls back to the
    plain rollout when no mesh is given).  In a loop, build the callable
    once with the factory instead — this wrapper re-traces per call."""
    if mesh is None:
        return rollout(adapter, params, cond, key, scheduler, num_steps,
                       sde_mask)
    return make_rollout_sharded(adapter, scheduler, num_steps, mesh,
                                sde_mask)(params, cond, key)
