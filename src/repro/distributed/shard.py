"""``shard_map`` entry point: per-device independent rollouts.

The jit-with-sharding path (``sharding.jit_sample``) keeps multi-device
sampling numerically identical to single-device — the right tool for
training.  For pure *generation throughput* (filling a reward buffer,
serving bursts) cross-layout bit-equality is irrelevant; this entry point
instead hands each data shard its own fold of the PRNG key and runs the
rollout fully locally — zero cross-device communication, embarrassingly
parallel.  Consequently the samples differ from (are statistically
exchangeable with, not equal to) a single-device rollout of the same key.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.rollout import Trajectory, rollout
from repro.distributed.mesh import DATA_AXIS


def make_rollout_sharded(adapter, scheduler, num_steps: int, mesh: Mesh,
                         sde_mask=None):
    """Build the jitted per-shard rollout ONCE; returns
    ``fn(params, cond, key) -> Trajectory``.  Reuse the returned callable
    across calls (a generation loop) — rebuilding it per batch re-traces
    the whole rollout every time."""

    def local(params, cond_shard, key):
        k = jax.random.fold_in(key, jax.lax.axis_index(DATA_AXIS))
        return rollout(adapter, params, cond_shard, k, scheduler, num_steps,
                       sde_mask)

    out_specs = Trajectory(xs=P(None, DATA_AXIS), logps=P(None, DATA_AXIS),
                           ts=P(), sde_mask=P(), cond=P(DATA_AXIS))
    # check_rep=False: ts/sde_mask are replicated by construction (identical
    # computation per shard) but shard_map cannot prove it
    sharded = shard_map(local, mesh=mesh, in_specs=(P(), P(DATA_AXIS), P()),
                        out_specs=out_specs, check_rep=False)
    dp = mesh.shape[DATA_AXIS]

    def run(params, cond: jax.Array, key: jax.Array) -> Trajectory:
        if cond.shape[0] % dp != 0:
            raise ValueError(
                f"rollout batch {cond.shape[0]} is not divisible by the "
                f"data axis ({dp} devices)")
        return _jitted(params, cond, key)

    _jitted = jax.jit(sharded)
    return run


def rollout_sharded(adapter, params, cond: jax.Array, key: jax.Array,
                    scheduler, num_steps: int, mesh: Optional[Mesh],
                    sde_mask=None) -> Trajectory:
    """One-shot convenience over ``make_rollout_sharded`` (falls back to the
    plain rollout when no mesh is given).  In a loop, build the callable
    once with the factory instead — this wrapper re-traces per call."""
    if mesh is None:
        return rollout(adapter, params, cond, key, scheduler, num_steps,
                       sde_mask)
    return make_rollout_sharded(adapter, scheduler, num_steps, mesh,
                                sde_mask)(params, cond, key)
