"""``repro.distributed`` — data-parallel training subsystem.

The ROADMAP north-star's first scaling axis: a ``jax.sharding.Mesh`` with a
single "data" dimension over prompts×groups, sharded jit entry points for
the trainer's sample/rewards/update (``sharding``), sequential
gradient-accumulation microbatching (``microbatch``), and a ``shard_map``
per-device rollout for communication-free generation (``shard``).

Everything degrades to the exact single-device path when
``DistConfig.data_parallel`` resolves to one device: ``data_mesh`` returns
``None`` and the jit wrappers reduce to plain ``jax.jit``.  Testable on CPU
via ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
from repro.distributed.mesh import (DATA_AXIS, data_mesh,
                                    resolve_data_parallel)
from repro.distributed.microbatch import (accumulated_value_and_grad,
                                          chunk_batch)
from repro.distributed.shard import (make_rollout_keyed_sharded,
                                     make_rollout_sharded, rollout_sharded)
from repro.distributed.sharding import (batch_sharding, check_batch_divisible,
                                        jit_fused_step, jit_rewards,
                                        jit_sample, jit_update, replicated,
                                        traj_shardings)

__all__ = [
    "DATA_AXIS", "data_mesh", "resolve_data_parallel",
    "accumulated_value_and_grad", "chunk_batch",
    "make_rollout_keyed_sharded", "make_rollout_sharded", "rollout_sharded",
    "batch_sharding", "check_batch_divisible", "jit_fused_step",
    "jit_rewards", "jit_sample", "jit_update", "replicated",
    "traj_shardings",
]
