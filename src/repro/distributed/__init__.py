"""``repro.distributed`` — the distributed-training subsystem.

The ROADMAP north-star's scaling axes as ONE 2-D device mesh
(``("data", "model")``, ``mesh``): prompts×groups batches shard over
"data"; params and AdamW moments shard over "model" per the
:class:`PartitionPlan` (``sharding``) — FSDP-style for dense backbone
leaves, expert-parallel for MoE tables, head-parallel for attention/MLA
projections, all declared by the logical axes in ``repro.models.params``.
Sharded jit entry points for the trainer's sample/rewards/update consume
the plan; sequential gradient-accumulation microbatching lives in
``microbatch``; ``shard`` holds the ``shard_map`` per-device rollout for
communication-free generation and the serving engine's keyed executor.

Everything degrades by construction: ``dp×mp=1`` resolves to no mesh and
plain ``jax.jit`` (the exact single-device path); ``mp=1`` builds the
historical 1-D "data" mesh with fully replicated params (bit-identical to
the pre-"model"-axis subsystem); and layouts are a runtime choice —
checkpoints move freely between ``dp=4`` and ``dp=2×mp=2`` through the
canonical unsharded on-disk layout.  Testable on CPU via
``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""
from repro.distributed.mesh import (DATA_AXIS, MODEL_AXIS, data_mesh,
                                    mesh_dp, mesh_mp, resolve_axes,
                                    resolve_data_parallel,
                                    resolve_model_parallel, train_mesh)
from repro.distributed.microbatch import (accumulated_value_and_grad,
                                          chunk_batch)
from repro.distributed.shard import (make_rollout_keyed_sharded,
                                     make_rollout_sharded, rollout_sharded)
from repro.distributed.sharding import (PartitionPlan, batch_sharding,
                                        check_batch_divisible,
                                        jit_fused_step, jit_rewards,
                                        jit_sample, jit_update,
                                        partition_plan, replicated,
                                        traj_shardings)

__all__ = [
    "DATA_AXIS", "MODEL_AXIS", "data_mesh", "train_mesh", "mesh_dp",
    "mesh_mp", "resolve_axes", "resolve_data_parallel",
    "resolve_model_parallel",
    "accumulated_value_and_grad", "chunk_batch",
    "make_rollout_keyed_sharded", "make_rollout_sharded", "rollout_sharded",
    "PartitionPlan", "partition_plan", "batch_sharding",
    "check_batch_divisible", "jit_fused_step", "jit_rewards", "jit_sample",
    "jit_update", "replicated", "traj_shardings",
]
