"""Sharded jit entry points for the trainer's sample / rewards / update,
and the :class:`PartitionPlan` mapping params to mesh layouts.

Layout: every batch-major array (trajectories, rewards, advantages,
condition embeddings) is sharded over the mesh "data" axis on its batch
dimension.  Parameters and AdamW moments are laid out per the
:class:`PartitionPlan` — replicated when ``model_parallel=1`` (pure data
parallelism, bit-identical to the historical 1-D path), or sharded along
the "model" axis otherwise: FSDP-style for dense backbone leaves, expert-
parallel for MoE tables, head-parallel for attention/MLA projections, as
declared by the per-module logical axes in ``repro.models.params``
(:data:`repro.models.params.MODEL_SHARDABLE` orders the priorities).  All
entry points are ``jax.jit`` with explicit ``in_shardings`` /
``out_shardings``; XLA's SPMD partitioner inserts the collectives (grad
all-reduce over "data", the gather / reduce-scatter pair around sharded
params over "model"), which keeps the math bit-comparable with the
single-device path up to floating-point reduction order.

``Trajectory`` batch-axis positions: ``xs`` (T+1, B, ...) and ``logps``
(T, B) carry batch on axis 1; ``cond`` on axis 0; ``ts``/``sde_mask`` are
replicated schedule arrays.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.rollout import Trajectory
from repro.distributed.mesh import DATA_AXIS, MODEL_AXIS, mesh_dp, mesh_mp
from repro.models import params as params_lib


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


def batch_sharding(mesh: Mesh, axis: int = 0) -> NamedSharding:
    """Shard dimension ``axis`` over the data axis (batch-major layout)."""
    return NamedSharding(mesh, PartitionSpec(*([None] * axis + [DATA_AXIS])))


def traj_shardings(mesh: Mesh) -> Trajectory:
    """Per-field shardings of a grouped Trajectory."""
    return Trajectory(
        xs=batch_sharding(mesh, 1),
        logps=batch_sharding(mesh, 1),
        ts=replicated(mesh),
        sde_mask=replicated(mesh),
        cond=batch_sharding(mesh, 0),
    )


# --------------------------------------------------------------------- plan

def _key_name(k) -> str:
    # DictKey -> .key, SequenceKey -> .idx, GetAttrKey (NamedTuples such as
    # RLState/AdamWState) -> .name
    for attr in ("key", "idx", "name"):
        v = getattr(k, attr, None)
        if v is not None:
            return str(v)
    return str(k)


def _path_names(path) -> tuple:
    return tuple(_key_name(k) for k in path)


class PartitionPlan:
    """Maps every param pytree leaf — and any state leaf mirroring one,
    i.e. the AdamW moments — to a :class:`NamedSharding` on the train mesh.

    Data-driven: built from the model's param *spec* tree (the same
    :class:`repro.models.params.P` leaves that carry shapes and
    initializers), so the plan can never drift from the parameter
    structure and no module-name ``if`` ladder exists anywhere.  Each leaf
    shards at most one dim over the "model" axis, chosen by
    :func:`repro.models.params.model_shard_dim`; everything else (and the
    whole plan when ``model_parallel=1``) is replicated, which makes the
    ``mp=1`` jit layouts identical to the historical replicated path.

    Layouts are a *runtime* choice: checkpoints save/restore through the
    canonical unsharded layout (``jax.device_get`` gathers on save), so a
    state written under one plan restores under any other via
    ``jax.device_put(state, plan.state_shardings(state))``.
    """

    def __init__(self, mesh: Mesh, spec):
        self.mesh = mesh
        self.spec = spec
        self.model_parallel = mesh_mp(mesh)
        self._param_shardings = None

    def param_specs(self):
        """Pytree (matching the param structure) of PartitionSpecs."""
        mp = self.model_parallel

        def one(p):
            dim = params_lib.model_shard_dim(p.shape, p.axes, mp)
            if dim is None:
                return PartitionSpec()
            entries = [None] * len(p.shape)
            entries[dim] = MODEL_AXIS
            return PartitionSpec(*entries)

        return jax.tree.map(one, self.spec, is_leaf=params_lib._is_p)

    def param_shardings(self):
        """Pytree (matching the param structure) of NamedShardings."""
        if self._param_shardings is None:
            self._param_shardings = jax.tree.map(
                lambda s: NamedSharding(self.mesh, s), self.param_specs(),
                is_leaf=lambda x: isinstance(x, PartitionSpec))
        return self._param_shardings

    def _table(self):
        """[(param path names, shape, sharding)] for suffix matching."""
        flat, _ = jax.tree_util.tree_flatten_with_path(
            self.spec, is_leaf=params_lib._is_p)
        shardings = jax.tree.leaves(
            self.param_shardings(),
            is_leaf=lambda x: isinstance(x, NamedSharding))
        return [(_path_names(path), tuple(p.shape), sh)
                for (path, p), sh in zip(flat, shardings)]

    def state_shardings(self, state):
        """Sharding pytree for a full train state (``RLState``): each state
        leaf whose pytree path ends with a param's path — the AdamW ``mu`` /
        ``nu`` moments are ``tree.map`` images of params, so their subtree
        paths match exactly — inherits that param's sharding (the FSDP
        contract: moments shard with their param); everything else (step
        counters, scalars) is replicated.  Structural, not name-based: no
        optimizer-specific knowledge lives here."""
        rep = replicated(self.mesh)
        table = self._table()

        def one(path, leaf):
            names = _path_names(path)
            shape = tuple(jnp.shape(leaf))
            best = None
            for pnames, pshape, sh in table:
                if (pshape == shape and len(pnames) <= len(names)
                        and names[len(names) - len(pnames):] == pnames):
                    if best is None or len(pnames) > len(best[0]):
                        best = (pnames, sh)
            return best[1] if best is not None else rep

        flat, treedef = jax.tree_util.tree_flatten_with_path(state)
        return jax.tree_util.tree_unflatten(
            treedef, [one(p, leaf) for p, leaf in flat])

    def bytes_report(self, state) -> Dict[str, int]:
        """Host-side byte accounting under this plan: the canonical
        (unsharded) total vs what one device actually holds — the FSDP win
        ``perf.log_memory`` surfaces.  Equal when nothing is sharded."""
        shardings = jax.tree.leaves(
            self.state_shardings(state),
            is_leaf=lambda x: isinstance(x, NamedSharding))
        total = per_dev = sharded = 0
        for leaf, sh in zip(jax.tree.leaves(state), shardings):
            size = 1
            for d in jnp.shape(leaf):
                size *= int(d)
            nbytes = size * jnp.dtype(jnp.result_type(leaf)).itemsize
            denom = 1
            for entry in sh.spec:
                if entry is None:
                    continue
                for ax in (entry if isinstance(entry, tuple) else (entry,)):
                    denom *= int(self.mesh.shape[ax])
            total += nbytes
            per_dev += nbytes // denom
            sharded += denom > 1
        return {"total_bytes": int(total), "per_device_bytes": int(per_dev),
                "sharded_leaves": int(sharded)}


def partition_plan(mesh: Optional[Mesh], spec) -> Optional[PartitionPlan]:
    """The PartitionPlan for ``mesh`` over a model's param ``spec`` tree
    (None for the single-device no-mesh path)."""
    if mesh is None:
        return None
    return PartitionPlan(mesh, spec)


# --------------------------------------------------------------- validation

def check_batch_divisible(batch: int, mesh: Optional[Mesh],
                          microbatch: int = 0) -> None:
    """Clear trace-time errors instead of opaque reshard/pad behavior."""
    if microbatch and microbatch > 1 and batch % microbatch != 0:
        raise ValueError(
            f"batch size {batch} is not divisible by dist.microbatch="
            f"{microbatch}; pick a microbatch count that divides "
            f"num_prompts × group_size")
    per_chunk = batch // microbatch if microbatch and microbatch > 1 else batch
    dp = mesh_dp(mesh)
    if dp > 1 and per_chunk % dp != 0:
        raise ValueError(
            f"per-update batch {per_chunk} (batch {batch}"
            + (f" / microbatch {microbatch}" if microbatch > 1 else "")
            + f") is not divisible by the mesh data axis ({dp} devices); "
            "adjust num_prompts/group_size so every device gets equal work")


# ------------------------------------------------------------- jit wrappers

def _plan_jit(fn: Callable, in_shardings, out_shardings=None):
    """Shared constructor for the non-donating sharded entry points.  The
    donating wrappers (``jit_update``/``jit_fused_step``) call ``jax.jit``
    directly instead, so the jaxlint scope graph keys their donation
    tracking off the literal ``donate_argnums`` keyword (R005); this helper
    is reached through the linter's *transitive* wrapper detection."""
    kw: Dict[str, Any] = {}
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    return jax.jit(fn, in_shardings=in_shardings, **kw)


def jit_sample(fn: Callable, mesh: Optional[Mesh], params_sharding=None):
    """``fn(params, cond, key, sde_mask) -> Trajectory`` — key/mask
    replicated, cond and the returned trajectory batch-sharded, params laid
    out per the PartitionPlan (``params_sharding`` — None replicates, the
    ``mp=1`` layout)."""
    if mesh is None:
        return jax.jit(fn)
    rep = replicated(mesh)
    psh = params_sharding if params_sharding is not None else rep
    return _plan_jit(fn, (psh, batch_sharding(mesh, 0), rep, rep),
                     traj_shardings(mesh))


def jit_rewards(fn: Callable, mesh: Optional[Mesh], *,
                with_params: bool = False):
    """``fn(x0, cond_meta[, reward_params]) -> (rewards, adv, stats)`` —
    batch-major inputs and outputs sharded over the data axis (the stats
    dict is scalar reductions, replicated by construction).
    ``with_params`` (``perf.offload_rewards``) accepts the host-offloaded
    reward-tower store as a third, replicated argument."""
    if mesh is None:
        return jax.jit(fn)
    b0 = batch_sharding(mesh, 0)
    if with_params:
        return _plan_jit(fn, (b0, b0, replicated(mesh)))
    return _plan_jit(fn, (b0, b0))


def jit_fused_step(fn: Callable, mesh: Optional[Mesh], state_sharding=None,
                   *, donate: bool = True, extras_sharding=None,
                   with_reward_params: bool = False):
    """``fn(state, cond_g, key, it, sde_mask, extras[, reward_params]) ->
    (state, metrics)`` — the ``repro.perf`` fused train step: RLState
    donated and laid out per the PartitionPlan (``state_sharding`` — None
    replicates), the group-repeated cond batch sharded over the data axis
    (the trajectory it becomes inside never crosses a jit boundary, so XLA
    propagates the batch sharding through rollout → rewards → update and
    inserts the same collectives the unfused path gets).  Donation
    rewrites the state in place per shard: in- and out-shardings are the
    same pytree.  ``extras_sharding`` lays out the ``update_extras()``
    tuple — None replicates; NFT's ref_params alias the placed params, so
    they arrive model-sharded under mp>1 and must be accepted in that
    layout.  ``with_reward_params`` (``perf.offload_rewards``) appends the
    host-offloaded reward-tower store as a trailing replicated argument."""
    donate_argnums = (0,) if donate else ()
    if mesh is None:
        return jax.jit(fn, donate_argnums=donate_argnums)
    rep = replicated(mesh)
    ssh = state_sharding if state_sharding is not None else rep
    esh = extras_sharding if extras_sharding is not None else rep
    in_sh = [ssh, batch_sharding(mesh, 0), rep, rep, rep, esh]
    if with_reward_params:
        in_sh.append(rep)
    return jax.jit(
        fn,
        in_shardings=tuple(in_sh),
        out_shardings=(ssh, rep),
        donate_argnums=donate_argnums)


def jit_update(fn: Callable, mesh: Optional[Mesh], state_sharding=None, *,
               donate: bool = True, extras_sharding=None):
    """``fn(state, traj, adv, key, extras) -> (state, metrics)`` — RLState
    donated and laid out per the PartitionPlan (``state_sharding`` — None
    replicates; params + AdamW moments rewritten in place per shard),
    trajectory/advantages batch-sharded; XLA all-reduces the grads over
    "data" and gathers/reduce-scatters sharded params over "model".
    ``extras_sharding`` lays out the ``update_extras()`` tuple — None
    replicates (see :func:`jit_fused_step`)."""
    donate_argnums = (0,) if donate else ()
    if mesh is None:
        return jax.jit(fn, donate_argnums=donate_argnums)
    rep = replicated(mesh)
    ssh = state_sharding if state_sharding is not None else rep
    esh = extras_sharding if extras_sharding is not None else rep
    return jax.jit(
        fn,
        in_shardings=(ssh, traj_shardings(mesh), batch_sharding(mesh, 0),
                      rep, esh),
        out_shardings=(ssh, rep),
        donate_argnums=donate_argnums)
