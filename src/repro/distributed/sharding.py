"""Sharded jit entry points for the trainer's sample / rewards / update.

Layout: every batch-major array (trajectories, rewards, advantages,
condition embeddings) is sharded over the mesh "data" axis on its batch
dimension; parameters and optimizer state are replicated (pure data
parallelism — FSDP layouts live in ``repro.sharding`` rule tables and can
be layered on later).  All entry points are ``jax.jit`` with explicit
``in_shardings``/``out_shardings``; XLA's SPMD partitioner inserts the
(grad-all-reduce) collectives, which keeps the math bit-comparable with the
single-device path up to floating-point reduction order.

``Trajectory`` batch-axis positions: ``xs`` (T+1, B, ...) and ``logps``
(T, B) carry batch on axis 1; ``cond`` on axis 0; ``ts``/``sde_mask`` are
replicated schedule arrays.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.core.rollout import Trajectory
from repro.distributed.mesh import DATA_AXIS


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, PartitionSpec())


def batch_sharding(mesh: Mesh, axis: int = 0) -> NamedSharding:
    """Shard dimension ``axis`` over the data axis (batch-major layout)."""
    return NamedSharding(mesh, PartitionSpec(*([None] * axis + [DATA_AXIS])))


def traj_shardings(mesh: Mesh) -> Trajectory:
    """Per-field shardings of a grouped Trajectory."""
    return Trajectory(
        xs=batch_sharding(mesh, 1),
        logps=batch_sharding(mesh, 1),
        ts=replicated(mesh),
        sde_mask=replicated(mesh),
        cond=batch_sharding(mesh, 0),
    )


def check_batch_divisible(batch: int, mesh: Optional[Mesh],
                          microbatch: int = 0) -> None:
    """Clear trace-time errors instead of opaque reshard/pad behavior."""
    if microbatch and microbatch > 1 and batch % microbatch != 0:
        raise ValueError(
            f"batch size {batch} is not divisible by dist.microbatch="
            f"{microbatch}; pick a microbatch count that divides "
            f"num_prompts × group_size")
    per_chunk = batch // microbatch if microbatch and microbatch > 1 else batch
    if mesh is not None:
        dp = mesh.shape[DATA_AXIS]
        if per_chunk % dp != 0:
            raise ValueError(
                f"per-update batch {per_chunk} (batch {batch}"
                + (f" / microbatch {microbatch}" if microbatch > 1 else "")
                + f") is not divisible by dist.data_parallel={dp}; adjust "
                "num_prompts/group_size so every device gets equal work")


def jit_sample(fn: Callable, mesh: Optional[Mesh]):
    """``fn(params, cond, key, sde_mask) -> Trajectory`` — params/key/mask
    replicated, cond and the returned trajectory batch-sharded."""
    if mesh is None:
        return jax.jit(fn)
    rep = replicated(mesh)
    return jax.jit(
        fn,
        in_shardings=(rep, batch_sharding(mesh, 0), rep, rep),
        out_shardings=traj_shardings(mesh))


def jit_rewards(fn: Callable, mesh: Optional[Mesh]):
    """``fn(x0, cond_meta) -> (rewards, adv, stats)`` — batch-major inputs
    and outputs sharded over the data axis (the stats dict is scalar
    reductions, replicated by construction)."""
    if mesh is None:
        return jax.jit(fn)
    b0 = batch_sharding(mesh, 0)
    return jax.jit(fn, in_shardings=(b0, b0))


def jit_fused_step(fn: Callable, mesh: Optional[Mesh], *,
                   donate: bool = True):
    """``fn(state, cond_g, key, it, sde_mask, extras) -> (state, metrics)``
    — the ``repro.perf`` fused train step: RLState replicated and donated,
    the group-repeated cond batch sharded over the data axis (the
    trajectory it becomes inside never crosses a jit boundary, so XLA
    propagates the batch sharding through rollout → rewards → update and
    inserts the same grad all-reduce the unfused path gets)."""
    donate_argnums = (0,) if donate else ()
    if mesh is None:
        return jax.jit(fn, donate_argnums=donate_argnums)
    rep = replicated(mesh)
    return jax.jit(
        fn,
        in_shardings=(rep, batch_sharding(mesh, 0), rep, rep, rep, rep),
        out_shardings=(rep, rep),
        donate_argnums=donate_argnums)


def jit_update(fn: Callable, mesh: Optional[Mesh], *, donate: bool = True):
    """``fn(state, traj, adv, key, extras) -> (state, metrics)`` — RLState
    replicated and donated (params + AdamW moments rewritten in place),
    trajectory/advantages batch-sharded; XLA all-reduces the grads."""
    donate_argnums = (0,) if donate else ()
    if mesh is None:
        return jax.jit(fn, donate_argnums=donate_argnums)
    rep = replicated(mesh)
    return jax.jit(
        fn,
        in_shardings=(rep, traj_shardings(mesh), batch_sharding(mesh, 0),
                      rep, rep),
        out_shardings=(rep, rep),
        donate_argnums=donate_argnums)
