"""Device-mesh construction for distributed RL training.

Two axes: ``"data"`` — sharded over prompts×groups batches — and
``"model"`` — params and AdamW moments sharded over it per the
:class:`repro.distributed.PartitionPlan`.  The mesh is only built when more
than one device participates: ``train_mesh`` returns ``None`` for
``dp×mp=1`` so every caller degrades to the exact single-device code path
(plain ``jax.jit``, no resharding, no collectives).  With ``mp=1`` the mesh
is the historical 1-D ``("data",)`` layout — bit-identical to the
replicated path this module shipped before the second axis existed.

Axis resolution (``resolve_axes``): a configured size of 0 means "auto" —
``data_parallel=0`` claims every local device *not* claimed by
``model_parallel``; ``model_parallel=0`` claims every device not claimed
by ``data_parallel`` (both 0 resolves to all-data, the historical
``data_parallel=0`` meaning).  ``dp×mp`` is validated against
``jax.local_device_count()`` with an actionable XLA_FLAGS hint.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from repro.config import DistConfig

DATA_AXIS = "data"
MODEL_AXIS = "model"


def _resolve_axis(name: str, requested: int, available: int,
                  total: Optional[int] = None) -> int:
    """Resolve one mesh-axis size: 0 -> all ``available`` devices, otherwise
    the configured count validated against what is actually there.  ``total``
    is the whole-mesh device count to suggest in the over-subscription hint
    (defaults to the requested axis size)."""
    if requested < 0:
        raise ValueError(f"dist.{name} must be >= 0, got {requested}")
    if requested == 0:
        return max(available, 1)
    if requested > available:
        want = total or requested
        raise ValueError(
            f"dist.{name}={requested} but only {available} device(s) are "
            f"available for this axis — launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={want} (CPU) or on a "
            f"{want}-device accelerator host")
    return requested


def resolve_axes(dist: DistConfig) -> tuple:
    """``(data_parallel, model_parallel)`` resolved against the local device
    count.  0 on either axis means "all devices not claimed by the other":
    ``model_parallel`` is resolved first when explicitly configured, so
    ``data_parallel=0`` fills the remainder; with ``model_parallel=0`` the
    data axis resolves first and the model axis takes what is left."""
    n_local = jax.local_device_count()
    dp_req = dist.data_parallel
    mp_req = getattr(dist, "model_parallel", 1)
    if mp_req == 0:
        dp = _resolve_axis("data_parallel", dp_req, n_local)
        mp = n_local // dp
    else:
        mp = _resolve_axis("model_parallel", mp_req, n_local)
        dp = _resolve_axis("data_parallel", dp_req, n_local // mp,
                           total=dp_req * mp if dp_req > 0 else None)
    return dp, mp


def resolve_data_parallel(dist: DistConfig) -> int:
    """Resolved "data" axis size (see :func:`resolve_axes`)."""
    return resolve_axes(dist)[0]


def resolve_model_parallel(dist: DistConfig) -> int:
    """Resolved "model" axis size (see :func:`resolve_axes`)."""
    return resolve_axes(dist)[1]


def train_mesh(dist: DistConfig) -> Optional[Mesh]:
    """The training mesh over the first ``dp×mp`` *local* devices (counts
    were validated against local_device_count — in a multi-process run
    jax.devices() would include other hosts' non-addressable devices):

    * ``dp×mp == 1`` -> ``None`` (exact single-device fast path);
    * ``mp == 1``    -> 1-D ``Mesh((dp,), ("data",))`` — literally the
      historical data-parallel mesh, so jit layouts are bit-identical to
      the pre-"model"-axis path;
    * otherwise      -> 2-D ``Mesh((dp, mp), ("data", "model"))``.
    """
    dp, mp = resolve_axes(dist)
    if dp * mp <= 1:
        return None
    devices = jax.local_devices()[:dp * mp]
    if mp == 1:
        return Mesh(devices, (DATA_AXIS,))
    if not jax.config.jax_threefry_partitionable:
        # non-partitionable threefry is not sharding-invariant on a 2-D
        # mesh: a batch-sharded jax.random draw produces different bits
        # than the same program replicated, which would make 2-D rollouts
        # sample different trajectories than every other layout.  The
        # partitionable implementation is invariant by construction.
        # Flipping the flag changes the random stream, so it happens only
        # when a model axis actually exists — dp-only and single-device
        # runs keep today's bits exactly; within an mp>1 process every
        # layout (including the single-device reference the equivalence
        # tests compare against) then draws the same stream.
        jax.config.update("jax_threefry_partitionable", True)
    return Mesh(np.asarray(devices).reshape(dp, mp),
                (DATA_AXIS, MODEL_AXIS))


def data_mesh(dist: DistConfig) -> Optional[Mesh]:
    """Compatibility alias for :func:`train_mesh` (the historical 1-D entry
    point; the returned mesh is 2-D whenever ``model_parallel > 1``)."""
    return train_mesh(dist)


def mesh_dp(mesh: Optional[Mesh]) -> int:
    """Size of the "data" axis (1 for no mesh)."""
    return 1 if mesh is None else int(mesh.shape.get(DATA_AXIS, 1))


def mesh_mp(mesh: Optional[Mesh]) -> int:
    """Size of the "model" axis (1 for no mesh or a 1-D data mesh)."""
    return 1 if mesh is None else int(mesh.shape.get(MODEL_AXIS, 1))
