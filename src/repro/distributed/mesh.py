"""Device-mesh construction for data-parallel RL training.

One axis — ``"data"`` — sharded over prompts×groups.  The mesh is only
built when more than one device participates: ``data_mesh`` returns ``None``
for ``data_parallel=1`` so every caller degrades to the exact single-device
code path (plain ``jax.jit``, no resharding, no collectives).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh

from repro.config import DistConfig

DATA_AXIS = "data"


def resolve_data_parallel(dist: DistConfig) -> int:
    """0 -> all local devices; otherwise the configured count, validated."""
    n_local = jax.local_device_count()
    dp = dist.data_parallel
    if dp < 0:
        raise ValueError(f"dist.data_parallel must be >= 0, got {dp}")
    if dp == 0:
        return n_local
    if dp > n_local:
        raise ValueError(
            f"dist.data_parallel={dp} but only {n_local} device(s) are "
            f"visible — launch with XLA_FLAGS="
            f"--xla_force_host_platform_device_count={dp} (CPU) or on a "
            f"{dp}-device accelerator host")
    return dp


def data_mesh(dist: DistConfig) -> Optional[Mesh]:
    """``Mesh((dp,), ("data",))`` over the first dp *local* devices (the
    count was validated against local_device_count — in a multi-process run
    jax.devices() would include other hosts' non-addressable devices), or
    ``None`` when a single device participates (single-device fast path)."""
    dp = resolve_data_parallel(dist)
    if dp <= 1:
        return None
    return Mesh(jax.local_devices()[:dp], (DATA_AXIS,))
