"""Gradient-accumulation microbatching.

Batches of ``group_size × num_prompts`` trajectories that do not fit device
memory are split on the batch axis into ``k`` sequential chunks; per-chunk
gradients are accumulated in float32 and averaged, so the optimizer sees the
same mean-over-batch gradient as a single full-batch pass (identical up to
floating-point summation order — asserted tightly by
``tests/test_distributed.py``).  Because every loss here is a mean over the
batch and chunks are equal-sized, mean-over-chunks == mean-over-batch for
the loss and gradients; non-linear *diagnostics* (e.g. ``adv_std``) become
the mean of per-chunk values, which is documented, not fixed — metrics are
monitoring, gradients are training.  Losses with batch-global statistics
(GRPO-Guard's RatioNorm) are *rejected* at trainer construction
(``BaseTrainer.microbatch_safe``) rather than silently made chunk-local.

The chunk loop is a ``lax.scan``, so only one chunk's activations are live
at a time — peak memory scales with ``B/k``, not ``B``.

Each chunk's loss sees the shared ``key`` folded with its chunk index, so
key-consuming losses (NFT/AWM timestep + noise draws) get independent draws
per chunk rather than k copies of one realization.  For those losses
microbatching is therefore *statistically* equivalent to full-batch (a
different but equally valid Monte-Carlo sample), while key-ignoring losses
(the GRPO family) keep the numeric gradient-equality above.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.rollout import Trajectory

F32 = jnp.float32


def chunk_batch(x: jax.Array, axis: int, k: int) -> jax.Array:
    """Split dim ``axis`` (size B) into k chunks: leading chunk axis first."""
    s = x.shape
    x = x.reshape(s[:axis] + (k, s[axis] // k) + s[axis + 1:])
    return jnp.moveaxis(x, axis, 0)


def _acc_init(shape_dtype):
    dt = shape_dtype.dtype
    acc_dt = F32 if jnp.issubdtype(dt, jnp.floating) else dt
    return jnp.zeros(shape_dtype.shape, acc_dt)


def accumulated_value_and_grad(loss_fn, params, traj: Trajectory,
                               adv: jax.Array, key: jax.Array,
                               extras: Tuple[Any, ...], k: int):
    """((loss, aux), grads) of ``loss_fn`` averaged over ``k`` sequential
    batch chunks.  Caller validates ``B % k == 0``."""
    xs_c = chunk_batch(traj.xs, 1, k)
    lp_c = chunk_batch(traj.logps, 1, k)
    cond_c = chunk_batch(traj.cond, 0, k)
    adv_c = chunk_batch(adv, 0, k)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def one(idx, xs, lp, cond, adv_chunk):
        t = Trajectory(xs=xs, logps=lp, ts=traj.ts,
                       sde_mask=traj.sde_mask, cond=cond)
        return vg(params, t, adv_chunk, jax.random.fold_in(key, idx),
                  *extras)

    shapes = jax.eval_shape(one, jnp.int32(0), xs_c[0], lp_c[0], cond_c[0],
                            adv_c[0])
    acc0 = jax.tree.map(_acc_init, shapes)

    def body(acc, inp):
        out = one(*inp)
        return jax.tree.map(lambda a, o: a + o.astype(a.dtype), acc, out), None

    acc, _ = jax.lax.scan(
        body, acc0, (jnp.arange(k, dtype=jnp.int32), xs_c, lp_c, cond_c,
                     adv_c))
    return jax.tree.map(lambda a, s: (a / k).astype(s.dtype), acc, shapes)
