"""Dependency-hygiene gate (run by ``make verify``).

Imports every core module and asserts that nothing outside the declared
runtime dependency set (jax, numpy, + soft-gated zstandard/msgpack) was
pulled in.  This is the regression class that once broke collection of the
entire test suite (``ModuleNotFoundError: No module named 'dacite'``).

Also asserts the stricter contract of ``repro.analysis`` (jaxlint): it
must import with jax AND numpy blocked — linting is stdlib-``ast`` only
and must never pay jax's import/device-init cost.
"""
import importlib
import sys

ANALYSIS_MODULES = [
    "repro.analysis",
    "repro.analysis.core",
    "repro.analysis.scopes",
    "repro.analysis.rules",
    "repro.analysis.baseline",
    "repro.analysis.cli",
]

CORE_MODULES = [
    "repro",
    "repro.config",
    "repro.registry",
    "repro.configs",
    "repro.api",
    "repro.checkpoint",
    "repro.core.preprocess",
    "repro.data.prompts",
    "repro.distributed",
    "repro.optim",
    "repro.perf",
    "repro.serving",
]

# third-party packages that must never be a hard requirement of the core
# path: dropped deps (dacite), heavyweight alternatives we build from
# scratch, and the soft-gated pair (zstandard/msgpack) whose fallback
# branches (raw-npz cache blobs, JSON checkpoint manifests) this gate
# forces every import to exercise
FORBIDDEN = ["dacite", "orbax", "optax", "flax", "hypothesis", "dm_haiku",
             "zstandard", "msgpack"]


def main() -> int:
    failures = []
    # jaxlint first, on a fully bare interpreter (jax/numpy blocked too) —
    # must run before anything imports jax for real
    analysis_forbidden = FORBIDDEN + ["jax", "numpy"]
    for name in analysis_forbidden:
        sys.modules[name] = None  # type: ignore[assignment]
    for mod in ANALYSIS_MODULES:
        try:
            importlib.import_module(mod)
        except Exception as e:  # noqa: BLE001
            failures.append(f"{mod} (stdlib-only): {type(e).__name__}: {e}")
    for name in analysis_forbidden:
        del sys.modules[name]

    for name in FORBIDDEN:
        sys.modules[name] = None  # type: ignore[assignment]  # force ImportError
    for mod in CORE_MODULES:
        try:
            importlib.import_module(mod)
        except Exception as e:  # noqa: BLE001
            failures.append(f"{mod}: {type(e).__name__}: {e}")
    for name in FORBIDDEN:
        del sys.modules[name]
    if failures:
        print("dependency check FAILED — core modules must import with only "
              "jax+numpy available:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"dependency check OK: {len(CORE_MODULES)} core modules import "
          f"without {FORBIDDEN}; {len(ANALYSIS_MODULES)} analysis modules "
          "import with jax+numpy blocked")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
