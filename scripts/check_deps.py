"""Dependency-hygiene gate (run by ``make verify``).

Imports every core module and asserts that nothing outside the declared
runtime dependency set (jax, numpy, + soft-gated zstandard/msgpack) was
pulled in.  This is the regression class that once broke collection of the
entire test suite (``ModuleNotFoundError: No module named 'dacite'``).
"""
import importlib
import sys

CORE_MODULES = [
    "repro",
    "repro.config",
    "repro.registry",
    "repro.configs",
    "repro.api",
    "repro.checkpoint",
    "repro.core.preprocess",
    "repro.data.prompts",
    "repro.distributed",
    "repro.optim",
    "repro.perf",
    "repro.serving",
]

# third-party packages that must never be a hard requirement of the core
# path: dropped deps (dacite), heavyweight alternatives we build from
# scratch, and the soft-gated pair (zstandard/msgpack) whose fallback
# branches (raw-npz cache blobs, JSON checkpoint manifests) this gate
# forces every import to exercise
FORBIDDEN = ["dacite", "orbax", "optax", "flax", "hypothesis", "dm_haiku",
             "zstandard", "msgpack"]


def main() -> int:
    for name in FORBIDDEN:
        sys.modules[name] = None  # type: ignore[assignment]  # force ImportError
    failures = []
    for mod in CORE_MODULES:
        try:
            importlib.import_module(mod)
        except Exception as e:  # noqa: BLE001
            failures.append(f"{mod}: {type(e).__name__}: {e}")
    for name in FORBIDDEN:
        del sys.modules[name]
    if failures:
        print("dependency check FAILED — core modules must import with only "
              "jax+numpy available:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"dependency check OK: {len(CORE_MODULES)} core modules import "
          f"without {FORBIDDEN}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
