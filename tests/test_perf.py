"""repro.perf — remat / fused-step / specialization / precision semantics.

Exactness contract under test (see repro/perf/__init__.py and the ROADMAP
"Performance" section):

* ``perf.remat="scan"``  : BIT-IDENTICAL to ``"none"`` on XLA:CPU — a
  ``jax.checkpoint`` around a ``lax.scan`` body is structurally isolated,
  so the rematerialized backward matches the original exactly (params
  compared bitwise after several optimizer steps).
* ``perf.remat="block"`` : f32-rounding-equal only — XLA re-fuses the
  open-graph remat; losses agree at rtol 1e-5 / atol 1e-6, and bf16
  parameters drift by single ulps once AdamW's rsqrt amplifies the noise.
* ``perf.fuse_step``     : same ops, different compiled program —
  parameters agree at rtol 1e-5 / atol 1e-6 after training steps.
* dead-branch specialization (all_sde / all_ode rollout bodies) is exact:
  it only removes computations whose results the mixed path discards.

The dist-composition tests run for real under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (``make verify``)
and skip on a single device.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, registry
from repro.config import (DistConfig, FlowRLConfig, OptimConfig, PerfConfig,
                          RewardSpec)
from repro.core import schedulers
from repro.core.rollout import rollout
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter

ARCH = configs.get_reduced("flux_dit")
FLOW = FlowRLConfig(num_steps=8, group_size=4, latent_tokens=8, latent_dim=8,
                    clip_range=0.2,
                    rewards=(RewardSpec("text_render", 1.0,
                             args={"latent_dim": 8, "latent_tokens": 8}),))
OPT = OptimConfig(lr=1e-3, total_steps=50, warmup_steps=2)
KEY = jax.random.PRNGKey(0)
COND = jax.random.normal(jax.random.PRNGKey(7), (2, 4, 512), jnp.float32)

# bf16 params: one ulp at |w|~0.25 is ~2e-3; AdamW's rsqrt amplifies
# single-ulp grad noise to a few ulps after a couple of steps
BF16_ATOL = 0.02


def make(trainer_type="flow_grpo", perf=None, dist=None, flow=FLOW):
    return registry.build("trainer", trainer_type, ARCH, flow, OPT,
                          key=jax.random.PRNGKey(0), dist=dist, perf=perf)


def run_steps(tr, n=2, cond=COND):
    m = None
    for it in range(n):
        m = tr.step(cond, KEY, it=it)
    jax.block_until_ready(tr.state.params)
    return jax.device_get(m)


def params_equal(a, b):
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def params_close(a, b, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------- remat

def test_remat_scan_bit_identical_training():
    base, scan = make(), make(perf=PerfConfig(remat="scan"))
    mb, ms = run_steps(base, 3), run_steps(scan, 3)
    assert params_equal(base.state.params, scan.state.params)
    assert mb["reward_mean"] == ms["reward_mean"]
    assert mb["loss"] == ms["loss"]


def test_remat_scan_bit_identical_mix_grpo():
    """The masked (non-static) MixGRPO body under scan checkpoint too."""
    base = make("mix_grpo")
    scan = make("mix_grpo", perf=PerfConfig(remat="scan"))
    run_steps(base), run_steps(scan)
    assert params_equal(base.state.params, scan.state.params)


def test_remat_block_rounding_equal():
    base, blk = make(), make(perf=PerfConfig(remat="block"))
    traj = base.sample(base.state.params, COND, KEY, 0)
    _, adv, _ = base._rewards_jit(traj.x0, {"cond": traj.cond})
    lb = jax.jit(lambda p: base.loss_fn(p, traj, adv, KEY)[0])(
        base.state.params)
    lk = jax.jit(lambda p: blk.loss_fn(p, traj, adv, KEY)[0])(
        blk.state.params)
    np.testing.assert_allclose(float(lb), float(lk), rtol=1e-5, atol=1e-6)
    run_steps(base), run_steps(blk)
    params_close(base.state.params, blk.state.params,
                 rtol=BF16_ATOL, atol=BF16_ATOL)


def test_memory_temp_bytes_drop_with_scan_remat():
    """memory_analysis() regression: the loss scan's stored residuals
    dominate update temp memory at num_steps=8; scan remat must cut peak
    temp bytes strictly — and by ≥30%, the bench acceptance threshold
    (deterministic compile-time analysis, so asserted here too)."""
    cond = jax.ShapeDtypeStruct(COND.shape, COND.dtype)
    mems = {mode: make(perf=PerfConfig(remat=mode)).memory_stats(cond)
            for mode in ("none", "scan", "block")}
    temp = {mode: m["update"]["temp_bytes"] for mode, m in mems.items()}
    assert temp["scan"] < temp["none"], temp
    assert temp["block"] < temp["none"], temp
    assert temp["scan"] <= 0.7 * temp["none"], temp


# ---------------------------------------------------------------- fusion

@pytest.mark.parametrize("trainer_type", ["flow_grpo", "nft", "awm"])
def test_fused_step_matches_unfused(trainer_type):
    base = make(trainer_type)
    fused = make(trainer_type, perf=PerfConfig(fuse_step=True))
    assert fused._fused_jit is not None
    mb, mf = run_steps(base), run_steps(fused)
    params_close(base.state.params, fused.state.params)
    np.testing.assert_allclose(mb["reward_mean"], mf["reward_mean"],
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(mb["loss"], mf["loss"], rtol=1e-5, atol=1e-5)


def test_fused_composes_with_remat_and_microbatch():
    base = make()
    fused = make(perf=PerfConfig(remat="scan", fuse_step=True),
                 dist=DistConfig(microbatch=2))
    run_steps(base), run_steps(fused)
    # microbatching reorders the f32 grad reduction (test_distributed's
    # documented tolerance class); AdamW amplifies to bf16-ulp scale
    params_close(base.state.params, fused.state.params,
                 rtol=BF16_ATOL, atol=BF16_ATOL)


def test_step_metrics_are_device_scalars():
    """Both step paths return device values fetched with ONE device_get —
    reward_mean (weight_map-weighted) and per-reward means included."""
    for tr in (make(), make(perf=PerfConfig(fuse_step=True))):
        m = tr.step(COND, KEY, it=0)
        assert {"reward_mean", "reward/text_render:0", "loss",
                "grad_norm"} <= set(m)
        assert all(isinstance(v, jax.Array) for v in m.values()), {
            k: type(v) for k, v in m.items()}
        host = jax.device_get(m)
        w = tr.loader.weight_map()["text_render:0"]
        np.testing.assert_allclose(
            host["reward_mean"], w * host["reward/text_render:0"], rtol=1e-6)


def test_fuse_step_rejects_attached_engine():
    from repro.serving import ServingEngine
    tr = make(perf=PerfConfig(fuse_step=True))
    with pytest.raises(ValueError, match="fuse_step"):
        tr.attach_engine(ServingEngine.for_trainer(tr))


# --------------------------------------------- dead-branch specialization

def _adapter_setup():
    flow = FlowRLConfig(num_steps=6, latent_tokens=8, latent_dim=8)
    ad = FlowAdapter(ARCH, flow, 512)
    params = params_lib.init(ad.spec(), jax.random.PRNGKey(1), jnp.bfloat16)
    cond = jax.random.normal(jax.random.PRNGKey(2), (4, 4, 512), jnp.float32)
    return ad, params, cond


def test_rollout_all_sde_specialization_exact():
    ad, params, cond = _adapter_setup()
    sde = schedulers.build("flow_sde", 0.7)
    ones = jnp.ones((6,), bool)
    mixed = jax.jit(lambda p, c, k: rollout(ad, p, c, k, sde, 6, ones))(
        params, cond, KEY)
    spec = jax.jit(lambda p, c, k: rollout(ad, p, c, k, sde, 6, ones,
                                           sde_mode="all_sde"))(
        params, cond, KEY)
    assert np.array_equal(np.asarray(mixed.xs), np.asarray(spec.xs))
    assert np.array_equal(np.asarray(mixed.logps), np.asarray(spec.logps))


def test_rollout_all_ode_specialization_exact():
    ad, params, cond = _adapter_setup()
    ode = schedulers.build("ode", 0.0)
    ones = jnp.ones((6,), bool)
    mixed = jax.jit(lambda p, c, k: rollout(ad, p, c, k, ode, 6, ones))(
        params, cond, KEY)
    spec = jax.jit(lambda p, c, k: rollout(ad, p, c, k, ode, 6, ones,
                                           sde_mode="all_ode"))(
        params, cond, KEY)
    assert np.array_equal(np.asarray(mixed.xs), np.asarray(spec.xs))
    assert not np.asarray(spec.logps).any()


def test_rollout_scan_remat_exact():
    ad, params, cond = _adapter_setup()
    sde = schedulers.build("flow_sde", 0.7)
    plain = jax.jit(lambda p, c, k: rollout(ad, p, c, k, sde, 6))(
        params, cond, KEY)
    remat = jax.jit(lambda p, c, k: rollout(ad, p, c, k, sde, 6,
                                            remat="scan"))(params, cond, KEY)
    assert np.array_equal(np.asarray(plain.xs), np.asarray(remat.xs))


def test_trainer_static_sde_modes():
    assert make("flow_grpo").sde_mode == "all_sde"
    assert make("grpo_guard").sde_mode == "all_sde"
    assert make("mix_grpo").sde_mode == "mixed"
    assert make("nft").sde_mode == "all_ode"
    assert make("awm").sde_mode == "all_ode"


# ------------------------------------------------------- dtype policy

def test_policy_dtype_explicit_bf16_matches_default():
    """policy_dtype="bfloat16" is exactly today's implicit behaviour when
    params are stored bf16 — the knob makes the cast explicit, not new."""
    base = make()
    bf16 = make(perf=PerfConfig(policy_dtype="bfloat16"))
    run_steps(base), run_steps(bf16)
    assert params_equal(base.state.params, bf16.state.params)


def test_policy_dtype_f32_runs_and_differs():
    base = make()
    f32 = make(perf=PerfConfig(policy_dtype="float32"))
    x_t = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 8), jnp.float32)
    v_b = base.adapter.velocity(base.state.params, x_t,
                                jnp.full((2,), 0.5), COND)
    v_f = f32.adapter.velocity(f32.state.params, x_t,
                               jnp.full((2,), 0.5), COND)
    assert v_b.dtype == jnp.float32 and v_f.dtype == jnp.float32
    # f32 activations genuinely change the compute (bf16 rounding scale)
    np.testing.assert_allclose(np.asarray(v_b), np.asarray(v_f),
                               rtol=0.1, atol=0.1)
    assert not np.array_equal(np.asarray(v_b), np.asarray(v_f))
    m = run_steps(f32)
    assert np.isfinite(m["loss"])


def test_perf_config_validation():
    with pytest.raises(ValueError, match="perf.remat"):
        make(perf=PerfConfig(remat="blocks"))
    with pytest.raises(ValueError, match="policy_dtype"):
        make(perf=PerfConfig(policy_dtype="fp8"))


# ------------------------------------------------------ front-door plumbing

def test_experiment_perf_plumbing(tmp_path):
    from repro.api import Experiment
    exp = Experiment.from_cli([
        "--reduced", "--set", "perf.remat=scan",
        "--set", "perf.fuse_step=true",
        "--set", f"flow.cache_dir={tmp_path}/cache",
    ])
    tr = exp.build_trainer()
    assert tr.perf.remat == "scan" and tr._fused_jit is not None
    # perf is runtime policy, not experiment identity: checkpoints move
    # freely between perf configurations (like dist)
    assert "perf" not in exp._ckpt_identity()


# ------------------------------------------------- dist composition (dp=4)

needs_dp4 = pytest.mark.skipif(
    jax.local_device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


@needs_dp4
@pytest.mark.parametrize("pc", [
    PerfConfig(remat="scan", fuse_step=True),
    PerfConfig(remat="block"),
], ids=["scan+fused", "block"])
def test_perf_composes_with_data_parallel_microbatch(pc):
    """remat × fusion × dp=4 × microbatch=2 matches the plain single-device
    step at the documented f32/bf16-reduction-order tolerances."""
    base = make()
    tr = make(perf=pc, dist=DistConfig(data_parallel=4, microbatch=2))
    mb, mt = run_steps(base), run_steps(tr)
    params_close(base.state.params, tr.state.params,
                 rtol=BF16_ATOL, atol=BF16_ATOL)
    np.testing.assert_allclose(mb["reward_mean"], mt["reward_mean"],
                               rtol=1e-4, atol=1e-4)


@needs_dp4
def test_fused_memory_stats_under_mesh():
    cond = jax.ShapeDtypeStruct(COND.shape, COND.dtype)
    tr = make(perf=PerfConfig(remat="scan", fuse_step=True),
              dist=DistConfig(data_parallel=4))
    mem = tr.memory_stats(cond)
    assert mem["update"]["temp_bytes"] and mem["fused"]["temp_bytes"]
