import os

# smoke tests / benches must see ONE device (the dry-run sets its own flags
# in a fresh subprocess); keep kernels on the jnp reference path by default —
# kernel tests opt into interpret mode explicitly.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
