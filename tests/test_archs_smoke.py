"""Per-architecture smoke tests (assignment requirement): a REDUCED variant
of each assigned family runs one forward/train step and one prefill+decode
step on CPU; output shapes asserted, no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro import configs, optim
from repro.config import OptimConfig
from repro.models import tasks

ALL_ARCHS = configs.ARCH_IDS + configs.PAPER_ARCHS


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch, rng_key):
    cfg = configs.get_reduced(arch)
    p = tasks.init_params(cfg, rng_key, jnp.float32)
    batch = tasks.synthetic_batch(cfg, 2, 32, rng_key)
    step = jax.jit(tasks.make_train_step(
        cfg, OptimConfig(lr=0.01, total_steps=4)))
    st = tasks.TrainState(p, optim.adamw_init(p))
    st2, m = step(st, batch)
    assert jnp.isfinite(m["loss"]), m
    assert jnp.isfinite(m["grad_norm"])
    # params actually changed
    diff = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max(),
        st.params, st2.params))
    assert max(float(d) for d in diff) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_shapes(arch, rng_key):
    cfg = configs.get_reduced(arch)
    p = tasks.init_params(cfg, rng_key)
    batch = tasks.synthetic_batch(cfg, 2, 32, rng_key)
    logits, caches = jax.jit(tasks.make_prefill_step(cfg))(p, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, caches2 = jax.jit(tasks.make_decode_step(cfg))(
        p, caches, tok, jnp.int32(32))
    assert logits2.shape == (2, cfg.vocab_size)
    assert jnp.isfinite(logits2.astype(jnp.float32)).all()


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_reduced_config_bounds(arch):
    """Assignment contract: reduced = ≤2 layers, d_model ≤ 512, ≤4 experts."""
    cfg = configs.get_reduced(arch)
    assert cfg.n_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.n_experts <= 4


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The full configs carry the exact assigned hyper-parameters."""
    cfg = configs.get(arch)
    expected = {
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "smollm-360m": (32, 960, 15, 5, 2560, 49152),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == expected, (arch, got, expected)
    if arch == "deepseek-v2-236b":
        assert cfg.moe.n_experts == 160 and cfg.moe.top_k == 6
        assert cfg.moe.expert_d_ff == 1536 and cfg.mla.kv_lora_rank == 512
    if arch == "grok-1-314b":
        assert cfg.moe.n_experts == 8 and cfg.moe.top_k == 2
    if arch == "mamba2-370m":
        assert cfg.ssm.d_state == 128
    if arch == "zamba2-2.7b":
        assert cfg.ssm.d_state == 64 and cfg.hybrid.shared_attn
    if arch == "qwen3-32b":
        assert cfg.qk_norm
