"""Trainer tests: registry cross-combination (the O(M+N) claim), learning
signal (reward improves), algorithm-specific mechanics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, registry
from repro.config import FlowRLConfig, OptimConfig, RewardSpec

KEY = jax.random.PRNGKey(3)

TINY_FLOW = FlowRLConfig(
    num_steps=4, group_size=4, latent_tokens=8, latent_dim=8,
    clip_range=0.2,
    rewards=(RewardSpec("text_render", 1.0,
                        args={"latent_dim": 8, "latent_tokens": 8}),))
TINY_OPT = OptimConfig(lr=3e-4, total_steps=50, warmup_steps=2)

ALL_TRAINERS = ["flow_grpo", "mix_grpo", "grpo_guard", "nft", "awm"]


def _cond(P=2):
    return jax.random.normal(KEY, (P, 4, 512), jnp.float32)


@pytest.mark.parametrize("tname", ALL_TRAINERS)
@pytest.mark.parametrize("arch", ["flux_dit", "smollm-360m", "mamba2-370m"])
def test_cross_combination(tname, arch):
    """Any (trainer × backbone family) pair builds and steps from config
    alone — the paper's registry decoupling."""
    cfg = configs.get_reduced(arch)
    tr = registry.build("trainer", tname, cfg, TINY_FLOW, TINY_OPT, key=KEY)
    m = tr.step(_cond(), KEY, it=0)
    assert jnp.isfinite(m["loss"])
    assert jnp.isfinite(m["reward_mean"])


# learning-signal config: larger groups + batch and lr=1e-3 push the
# learning signal well above the per-iteration reward noise (~0.02), so the
# fixed-seed assertion holds with a >2x margin for every trainer (probed
# across seeds 0/1/3: flow_grpo delta >= +0.044, nft >= +0.09, awm larger)
LEARN_FLOW = FlowRLConfig(
    num_steps=4, group_size=8, latent_tokens=8, latent_dim=8,
    clip_range=0.2,
    rewards=(RewardSpec("text_render", 1.0,
                        args={"latent_dim": 8, "latent_tokens": 8}),))
LEARN_OPT = OptimConfig(lr=1e-3, total_steps=135, warmup_steps=2)


@pytest.mark.parametrize("tname", ["flow_grpo", "nft", "awm"])
def test_reward_improves(tname):
    """Fig. 2 reproduction at toy scale: reward increases over training."""
    cfg = configs.get_reduced("flux_dit")
    tr = registry.build("trainer", tname, cfg, LEARN_FLOW, LEARN_OPT, key=KEY)
    cond = _cond(8)
    hist = []
    for it in range(45):
        m = tr.step(cond, KEY, it=it)
        hist.append(float(m["reward_mean"]))
    early = np.mean(hist[:5])
    late = np.mean(hist[-10:])
    assert late > early + 0.02, (tname, early, late, hist)


def test_grpo_ratio_is_one_at_rollout_params():
    """Recomputing logp under the same params that sampled gives ratio 1 and
    clip_frac 0 on the first update."""
    cfg = configs.get_reduced("flux_dit")
    tr = registry.build("trainer", "flow_grpo", cfg, TINY_FLOW, TINY_OPT,
                        key=KEY)
    m = tr.step(_cond(), KEY, it=0)
    assert float(m["clip_frac"]) < 1e-6


def test_mix_grpo_masks():
    cfg = configs.get_reduced("flux_dit")
    flow = FlowRLConfig(**{**TINY_FLOW.__dict__, "sde_window": 2,
                           "sde_window_shift_every": 1})
    tr = registry.build("trainer", "mix_grpo", cfg, flow, TINY_OPT, key=KEY)
    m0 = np.asarray(tr.sde_mask(0))
    m3 = np.asarray(tr.sde_mask(3))
    assert m0.sum() == 2 and m3.sum() == 2
    assert not np.array_equal(m0, m3)        # window slides
    traj = tr.sample(tr.state.params, _cond(), KEY, it=0)
    logps = np.asarray(traj.logps)
    assert np.all(logps[~np.asarray(traj.sde_mask)] == 0.0)
    assert np.all(logps[np.asarray(traj.sde_mask)] != 0.0)


def test_guard_ratio_transform_centers():
    cfg = configs.get_reduced("flux_dit")
    tr = registry.build("trainer", "grpo_guard", cfg, TINY_FLOW, TINY_OPT,
                        key=KEY)
    ratio = jnp.asarray([0.5, 1.0, 1.5, 2.0])
    out = tr.ratio_transform(ratio, 0, jnp.bool_(True))
    np.testing.assert_allclose(float(out.mean()), 1.0, rtol=1e-5)


def test_nft_reflects_about_reference():
    """NFT loss is r-independent exactly at the reference policy (v⁻ == v⁺
    when θ == θ_ref) and becomes r-sensitive once θ moves — the reflection
    mechanics."""
    cfg = configs.get_reduced("flux_dit")
    tr = registry.build("trainer", "nft", cfg, TINY_FLOW, TINY_OPT, key=KEY)
    traj = tr.sample(tr.state.params, _cond(), KEY, it=0)
    hi0 = tr.loss_fn(tr.state.params, traj, jnp.full((8,), 5.0), KEY)[0]
    lo0 = tr.loss_fn(tr.state.params, traj, jnp.full((8,), -5.0), KEY)[0]
    assert jnp.allclose(hi0, lo0)        # at init θ == θ_ref
    tr.step(_cond(), KEY, it=0)          # move θ away from the reference
    traj = tr.sample(tr.state.params, _cond(), KEY, it=1)
    hi = tr.loss_fn(tr.state.params, traj, jnp.full((8,), 5.0), KEY)[0]
    lo = tr.loss_fn(tr.state.params, traj, jnp.full((8,), -5.0), KEY)[0]
    assert jnp.isfinite(hi) and jnp.isfinite(lo)
    assert not jnp.allclose(hi, lo)


def test_awm_advantage_clipping():
    cfg = configs.get_reduced("flux_dit")
    tr = registry.build("trainer", "awm", cfg, TINY_FLOW, TINY_OPT, key=KEY)
    traj = tr.sample(tr.state.params, _cond(), KEY, it=0)
    adv = jnp.asarray([100.0, -100.0] * 4)
    loss, aux = tr.loss_fn(tr.state.params, traj, adv, KEY)
    assert float(aux["adv_clip_frac"]) == 1.0
    assert jnp.isfinite(loss)


def test_solver_agnostic_rollouts_are_deterministic():
    """NFT/AWM sample with the ODE scheduler: same key, same trajectory; and
    no step carries log-probability."""
    cfg = configs.get_reduced("flux_dit")
    tr = registry.build("trainer", "awm", cfg, TINY_FLOW, TINY_OPT, key=KEY)
    t1 = tr.sample(tr.state.params, _cond(), jax.random.PRNGKey(1), it=0)
    t2 = tr.sample(tr.state.params, _cond(), jax.random.PRNGKey(2), it=0)
    # ODE: trajectories differ only through the initial noise (keys differ
    # -> differ); logps identically zero
    assert np.all(np.asarray(t1.logps) == 0.0)
    assert np.all(np.asarray(t2.logps) == 0.0)
