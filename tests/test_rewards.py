"""Multi-reward system tests (paper §2.3): interfaces, deduplication,
advantage aggregation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import RewardSpec
from repro.core.rewards import (MultiRewardLoader, compute_advantages,
                                group_normalize)

KEY = jax.random.PRNGKey(5)


def _cond_meta(B, cond_dim=512):
    return {"cond": jax.random.normal(KEY, (B, 4, cond_dim))}


def test_loader_dedup():
    """Three specs, two referencing the same frozen backbone -> 2 loads."""
    specs = (
        RewardSpec("pickscore", 1.0, model_id="pickscore-base"),
        RewardSpec("pref_group", 0.5, model_id="pickscore-base"),
        RewardSpec("text_render", 1.0),
    )
    loader = MultiRewardLoader(specs, KEY)
    assert len(loader) == 3
    assert loader.unique_loads == 2
    # shared param store: same object
    assert loader.models[0].params is loader.models[1].params


def test_pointwise_and_groupwise_interfaces():
    specs = (RewardSpec("pickscore", 1.0),
             RewardSpec("pref_group", 1.0))
    loader = MultiRewardLoader(specs, KEY)
    x0 = jax.random.normal(KEY, (8, 64, 16))
    rewards = loader.compute_all(x0, _cond_meta(8), group_size=4)
    assert set(rewards) == {"pickscore:0", "pref_group:1"}
    for r in rewards.values():
        assert r.shape == (8,)
    # groupwise win-rates live in [0, 1] and average 0.5 within a group
    pg = rewards["pref_group:1"].reshape(2, 4)
    assert bool(jnp.all((pg >= 0) & (pg <= 1)))
    np.testing.assert_allclose(pg.mean(axis=1), 0.5, atol=1e-5)


def test_group_normalize_properties():
    r = jax.random.normal(KEY, (24,)) * 3 + 5
    z = group_normalize(r, 8)
    zg = z.reshape(3, 8)
    np.testing.assert_allclose(zg.mean(1), 0.0, atol=1e-5)
    np.testing.assert_allclose(zg.std(1), 1.0, atol=1e-2)


def test_group_normalize_indivisible_batch_raises():
    """B % group_size != 0 must fail with a clear error naming both numbers,
    not an opaque reshape crash."""
    r = jax.random.normal(KEY, (10,))
    with pytest.raises(ValueError, match=r"10.*group_size 4"):
        group_normalize(r, 4)
    with pytest.raises(ValueError, match="group_size"):
        group_normalize(r, 0)


def test_group_repeat_invalid_group_size_raises():
    from repro.core.rollout import group_repeat
    cond = jax.random.normal(KEY, (2, 4, 8))
    with pytest.raises(ValueError, match="group_size"):
        group_repeat(cond, 0)
    assert group_repeat(cond, 3).shape == (6, 4, 8)


def test_weighted_sum_vs_gdpo():
    """GDPO decouples scales: a reward with 100× variance dominates
    weighted_sum but not gdpo."""
    k1, k2 = jax.random.split(KEY)
    small = jax.random.normal(k1, (16,))
    big = jax.random.normal(k2, (16,)) * 100.0
    rewards = {"a": small, "b": big}
    weights = {"a": 1.0, "b": 1.0}
    ws = compute_advantages("weighted_sum", rewards, weights, 8)
    gd = compute_advantages("gdpo", rewards, weights, 8)
    # weighted_sum advantage ≈ normalized big reward (it swamps a)
    corr_ws = jnp.corrcoef(ws, group_normalize(big, 8))[0, 1]
    assert float(corr_ws) > 0.98
    # gdpo balances both
    corr_gd_a = jnp.corrcoef(gd, group_normalize(small, 8))[0, 1]
    assert float(corr_gd_a) > 0.3


def test_new_aggregator_pluggable():
    from repro import registry
    name = "test_max_agg"
    if not registry.is_registered("aggregator", name):
        @registry.register("aggregator", name)
        def max_agg(rewards, weights, group_size):
            return group_normalize(
                jnp.maximum(*[rewards[k] for k in sorted(rewards)][:2]),
                group_size)
    rewards = {"a": jnp.arange(8.0), "b": -jnp.arange(8.0)}
    out = compute_advantages(name, rewards, {"a": 1, "b": 1}, 4)
    assert out.shape == (8,)
