"""repro.serving engine tests: bucket policy, remainder/padded batches,
per-request determinism (the keyed-rollout invariant all batching rests
on), deadline-flush admission, cond-cache behaviour, warmup, trainer
opt-in, and sharded-vs-single-device bit-identity (4 faked CPU host
devices, spawned in a subprocess so the tier-1 environment stays
single-device)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, registry
from repro.config import DistConfig, FlowRLConfig, OptimConfig, RewardSpec
from repro.core import schedulers
from repro.core.rollout import request_keys, rollout_keyed
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter
from repro.serving import BucketGrid, ServingEngine, default_buckets

KEY = jax.random.PRNGKey(7)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ARCH = configs.get_reduced("flux_dit")
FLOW = FlowRLConfig(num_steps=3, latent_tokens=8, latent_dim=8,
                    clip_range=0.2,
                    rewards=(RewardSpec("text_render", 1.0,
                             args={"latent_dim": 8, "latent_tokens": 8}),))
ADAPTER = FlowAdapter(ARCH, FLOW, 512)
PARAMS = params_lib.init(ADAPTER.spec(), KEY, jnp.float32)
SCHED = schedulers.build("flow_sde", 0.7)
COND = jax.random.normal(jax.random.PRNGKey(1), (7, 4, 512), jnp.float32)


class _Clock:
    """Injectable logical clock for deadline tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _engine(**kw):
    kw.setdefault("num_steps", FLOW.num_steps)
    kw.setdefault("max_batch", 4)
    kw.setdefault("cond_len", 4)
    return ServingEngine(ADAPTER, SCHED, kw.pop("params", PARAMS), **kw)


# ------------------------------------------------------------- bucket policy

def test_default_buckets_are_powers_of_two_up_to_max():
    assert default_buckets(8) == (1, 2, 4, 8)
    assert default_buckets(6) == (1, 2, 4, 6)
    assert default_buckets(1) == (1,)
    with pytest.raises(ValueError, match="max_batch"):
        default_buckets(0)


def test_bucket_grid_picks_smallest_covering_tier():
    g = BucketGrid(max_batch=8)
    assert [g.pick(n) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    with pytest.raises(ValueError, match="exceed"):
        g.pick(9)
    with pytest.raises(ValueError, match="bucket"):
        g.pick(0)


def test_bucket_grid_dp_alignment():
    """Sharded serving needs equal per-device slices: tiers round up to
    multiples of dp and collapse duplicates."""
    g = BucketGrid(max_batch=8, dp=4)
    assert g.sizes == (4, 8)
    assert g.pick(1) == 4 and g.pick(5) == 8
    g = BucketGrid([3, 5, 6], dp=2)
    assert g.sizes == (4, 6)


def test_bucket_grid_alignment_never_raises_memory_cap():
    """max_batch is a memory bound: dp-alignment clamps DOWN to the
    largest dp multiple <= the requested cap (dp itself only when the cap
    is below one lane per device — the smallest batch a mesh can run)."""
    assert BucketGrid(max_batch=6, dp=4).sizes == (4,)
    assert BucketGrid(max_batch=11, dp=4).sizes == (4, 8)
    assert BucketGrid([3], dp=4).sizes == (4,)          # below one/device
    # explicit tiers above the cap are a config error, not a silent OOM
    with pytest.raises(ValueError, match="max_batch"):
        BucketGrid([16], max_batch=8)


# --------------------------------------------------- batch shape correctness

def test_remainder_batch_returns_exactly_n_outputs():
    """7 requests through max_batch=4 => one full bucket + a padded
    remainder; exactly 7 latents come back, in request order."""
    eng = _engine()
    lat = eng.serve(COND, KEY)
    assert lat.shape == (7, 8, 8)
    assert np.isfinite(np.asarray(lat)).all()
    stats = eng.stats
    assert stats["dispatches"] == {(4, 3): 2}
    assert stats["padded_lanes"] == 1          # 3-request remainder in b=4
    # request order: row i is exactly the single-request serve of key i
    keys = request_keys(KEY, 7)
    eng2 = _engine()
    h = eng2.submit(cond=COND[5], key=keys[5])
    eng2.drain()
    np.testing.assert_array_equal(np.asarray(lat[5]),
                                  np.asarray(h.result()))


def test_per_request_determinism_across_batching():
    """Same request key => bit-identical latent whatever bucket grid,
    max_batch, or batch mates it is served with."""
    lat_a = _engine(max_batch=4).serve(COND, KEY)
    lat_b = _engine(max_batch=2).serve(COND, KEY)
    lat_c = _engine(max_batch=8, buckets=(3, 7, 8)).serve(COND, KEY)
    np.testing.assert_array_equal(np.asarray(lat_a), np.asarray(lat_b))
    np.testing.assert_array_equal(np.asarray(lat_a), np.asarray(lat_c))
    # a permuted batch serves each request identically too
    perm = [3, 0, 6, 1, 5, 2, 4]
    keys = request_keys(KEY, 7)
    eng = _engine(max_batch=4)
    handles = [eng.submit(cond=COND[i], key=keys[i]) for i in perm]
    eng.drain()
    for j, i in enumerate(perm):
        np.testing.assert_array_equal(np.asarray(handles[j].result()),
                                      np.asarray(lat_a[i]))


def test_rollout_keyed_masked_steps_integrate_plain_flow():
    """With eta>0, an sde_mask=False step must follow step_ode (x - v·Δ),
    NOT the SDE drift mean (whose sigma^2/2t correction is nonzero even
    when the noise is masked off) — the MixGRPO ODE-window contract that
    `rollout` implements and attach_engine must preserve."""
    from repro.core.rollout import mix_sde_mask
    mask = mix_sde_mask(3, 2)                     # [SDE, SDE, ODE]
    keys = request_keys(KEY, 4)
    traj = rollout_keyed(ADAPTER, PARAMS, COND[:4], keys, SCHED, 3, mask)
    for j in range(3):
        tb = jnp.full((4,), traj.ts[j], jnp.float32)
        v = ADAPTER.velocity(PARAMS, traj.xs[j], tb, COND[:4])
        x_ode = SCHED.step_ode(v, traj.xs[j], traj.ts[j], traj.ts[j + 1])
        if bool(mask[j]):
            # stochastic step: departs from the plain flow, logps recorded
            assert not np.allclose(np.asarray(traj.xs[j + 1]),
                                   np.asarray(x_ode), atol=1e-5)
            assert (np.asarray(traj.logps[j]) != 0).all()
        else:
            np.testing.assert_allclose(np.asarray(traj.xs[j + 1]),
                                       np.asarray(x_ode),
                                       atol=1e-6, rtol=1e-6)
            np.testing.assert_array_equal(np.asarray(traj.logps[j]), 0.0)


def test_rollout_keyed_batch_composition_invariance():
    """The primitive underneath: any sub-batch of (cond, keys) rows yields
    bit-identical per-row trajectories."""
    keys = request_keys(KEY, 5)
    full = rollout_keyed(ADAPTER, PARAMS, COND[:5], keys, SCHED, 3)
    sub = rollout_keyed(ADAPTER, PARAMS, COND[1:4], keys[1:4], SCHED, 3)
    np.testing.assert_array_equal(np.asarray(full.xs[:, 1:4]),
                                  np.asarray(sub.xs))
    np.testing.assert_array_equal(np.asarray(full.logps[:, 1:4]),
                                  np.asarray(sub.logps))
    with pytest.raises(ValueError, match="keys"):
        rollout_keyed(ADAPTER, PARAMS, COND[:5], keys[:4], SCHED, 3)


# ----------------------------------------------------- admission & deadlines

def test_full_bucket_dispatches_immediately():
    """Continuous batching: a full bucket never waits for the deadline."""
    clk = _Clock()
    eng = _engine(deadline_s=1e9, clock=clk)
    keys = request_keys(KEY, 4)
    handles = [eng.submit(cond=COND[i], key=keys[i]) for i in range(4)]
    assert all(h.done for h in handles)        # dispatched at 4th submit
    assert eng.pending() == 0
    assert eng.stats["dispatches"] == {(4, 3): 1}


def test_partial_bucket_waits_for_deadline_then_flushes():
    clk = _Clock()
    eng = _engine(deadline_s=0.5, clock=clk)
    keys = request_keys(KEY, 2)
    handles = [eng.submit(cond=COND[i], key=keys[i]) for i in range(2)]
    assert not any(h.done for h in handles) and eng.pending() == 2
    clk.t = 0.4
    assert eng.poll() == 0                     # deadline not reached
    assert eng.pending() == 2
    clk.t = 0.6
    assert eng.poll() == 2                     # oldest crossed the deadline
    assert all(h.done for h in handles)
    assert eng.stats["dispatches"] == {(2, 3): 1}   # smallest covering tier
    with pytest.raises(RuntimeError, match="not been served"):
        _engine(clock=_Clock(), deadline_s=1e9) \
            .submit(cond=COND[0], key=keys[0]).result()


def test_drain_flushes_everything_regardless_of_deadline():
    clk = _Clock()
    eng = _engine(deadline_s=1e9, clock=clk)
    keys = request_keys(KEY, 3)
    handles = [eng.submit(cond=COND[i], key=keys[i]) for i in range(3)]
    assert eng.drain() == 3 and all(h.done for h in handles)


def test_num_steps_tiers_are_separate_buckets():
    eng = _engine()
    h3 = eng.submit(cond=COND[0], seed=0)                 # default 3 steps
    h2 = eng.submit(cond=COND[1], seed=1, num_steps=2)
    eng.drain()
    assert h3.result().shape == h2.result().shape == (8, 8)
    assert set(eng.stats["dispatches"]) == {(1, 3), (1, 2)}
    assert not np.array_equal(np.asarray(h3.result()),
                              np.asarray(h2.result()))


# ------------------------------------------------------------ warmup & cache

def test_warmup_pretraces_grid_so_serving_never_compiles():
    eng = _engine()
    report = eng.warmup()
    assert set(report) == {"b1/s3", "b2/s3", "b4/s3"}
    assert all(dt > 0 for dt in report.values())
    eng.serve(COND, KEY)
    stats = eng.stats
    assert stats["cold_dispatches"] == 0
    assert stats["warmup_s"] > 0
    # an un-warmed engine serving the same load compiles on the hot path
    # (both dispatches share the (4, 3) shape, so exactly one cold trace)
    cold = _engine()
    cold.serve(COND, KEY)
    assert cold.stats["cold_dispatches"] == 1


def test_cond_cache_skips_encoder_for_repeat_prompts():
    from repro.core.preprocess import ConditionProvider
    provider = ConditionProvider(
        preprocessing=False,
        encoder_kw=dict(cond_dim=512, cond_len=4, vocab=256, hidden=32))
    eng = _engine(provider=provider)
    lat1 = eng.serve(["a fox", "a robot", "a fox"], KEY)
    cc = eng.stats["cond_cache"]
    assert cc == {"hits": 1, "misses": 2, "entries": 2}
    # same prompts + same base key again: all hits, identical latents
    lat2 = eng.serve(["a fox", "a robot", "a fox"], KEY)
    cc = eng.stats["cond_cache"]
    assert cc["hits"] == 4 and cc["misses"] == 2
    np.testing.assert_array_equal(np.asarray(lat1), np.asarray(lat2))


def test_cond_cache_lru_eviction():
    from repro.serving import CondCache
    c = CondCache(max_entries=2)
    c.put("a", np.zeros(1)); c.put("b", np.ones(1))
    assert c.get("a") is not None              # refresh "a"
    c.put("c", np.ones(1))                     # evicts "b" (LRU)
    assert c.get("b") is None and len(c) == 2
    assert c.get("a") is not None and c.get("c") is not None


def test_submit_validation():
    eng = _engine()
    with pytest.raises(ValueError, match="exactly one"):
        eng.submit()
    with pytest.raises(ValueError, match="exactly one"):
        eng.submit(cond=COND[0], prompt="both")
    with pytest.raises(ValueError, match="Lc, cond_dim"):
        eng.submit(cond=COND)                  # batch where a row belongs
    with pytest.raises(ValueError, match="ConditionProvider"):
        eng.submit(prompt="no provider attached")


# ------------------------------------------------------------ trainer opt-in

def test_trainer_attach_engine_end_to_end():
    """Online RL sampling through the serving engine: same Trajectory
    contract, per-request keyed, finite metrics through a full step."""
    tr = registry.build("trainer", "flow_grpo", ARCH, FLOW,
                        OptimConfig(lr=1e-3, total_steps=8, warmup_steps=2),
                        key=KEY, dtype=jnp.float32, dist=DistConfig())
    eng = ServingEngine.for_trainer(tr, max_batch=8, cond_len=4)
    tr.attach_engine(eng)
    cond = jax.random.normal(jax.random.PRNGKey(2), (3, 4, 512), jnp.float32)
    m = tr.step(cond, KEY, it=0)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["reward_mean"]))
    # 3 prompts x group 8 = 24 rollouts -> 3 capacity-8 chunks, no padding
    assert eng.stats["dispatches"] == {(8, 3): 3}
    # the engine rollout is the keyed primitive (jitted on both sides).
    # B=24-in-one-call vs three B=8 chunks may differ by reduction-order
    # ulps when XLA retiles matmuls at the larger shape (observed only
    # under the 4-faked-device flag), so this cross-shape check is
    # allclose; the *equal-shape* bit-identity contracts are asserted
    # exactly elsewhere in this file.
    traj = tr.sample(tr.state.params, cond, KEY, it=0)
    keys = request_keys(KEY, 24)
    from repro.core.rollout import group_repeat
    direct = jax.jit(lambda p, c, k: rollout_keyed(
        ADAPTER, p, c, k, tr.scheduler, 3))(
            tr.state.params, group_repeat(cond, 8), keys)
    np.testing.assert_allclose(np.asarray(traj.xs),
                               np.asarray(direct.xs),
                               atol=1e-5, rtol=1e-3)
    tr.attach_engine(None)                     # detach restores jit path
    traj2 = tr.sample(tr.state.params, cond, KEY, it=0)
    assert traj2.xs.shape == traj.xs.shape


def test_attach_engine_rejects_mismatched_components():
    """A foreign scheduler would make the update recompute log-probs under
    a DIFFERENT transition density than the one sampled — silently wrong
    ratios — so attach validates num_steps, scheduler, and mesh."""
    tr = registry.build("trainer", "flow_grpo", ARCH, FLOW,
                        OptimConfig(total_steps=8), key=KEY,
                        dtype=jnp.float32)
    with pytest.raises(ValueError, match="num_steps"):
        tr.attach_engine(_engine(num_steps=5))
    wrong_sched = ServingEngine(ADAPTER, schedulers.build("dance_sde", 0.3),
                                PARAMS, num_steps=FLOW.num_steps,
                                cond_len=4)
    with pytest.raises(ValueError, match="scheduler"):
        tr.attach_engine(wrong_sched)
    wrong_eta = ServingEngine(ADAPTER, schedulers.build("flow_sde", 0.1),
                              PARAMS, num_steps=FLOW.num_steps, cond_len=4)
    with pytest.raises(ValueError, match="scheduler"):
        tr.attach_engine(wrong_eta)


def test_engine_rollout_chunking_matches_single_dispatch():
    """B > capacity runs in capacity slices; the concatenated Trajectory is
    bit-identical to one unchunked keyed rollout."""
    eng = _engine(max_batch=4)
    cond = COND[:6]
    traj = eng.rollout(PARAMS, cond, KEY)
    direct = jax.jit(lambda p, c, k: rollout_keyed(
        ADAPTER, p, c, k, SCHED, 3))(PARAMS, cond, request_keys(KEY, 6))
    np.testing.assert_array_equal(np.asarray(traj.xs),
                                  np.asarray(direct.xs))
    np.testing.assert_array_equal(np.asarray(traj.logps),
                                  np.asarray(direct.logps))
    np.testing.assert_array_equal(np.asarray(traj.cond),
                                  np.asarray(direct.cond))
    # 6 = 4 + 2 -> second chunk rides the b2 tier, no padding at all
    assert eng.stats["dispatches"] == {(4, 3): 1, (2, 3): 1}
    assert eng.stats["padded_lanes"] == 0


# ------------------------------------------------- multi-device (subprocess)

def _run_with_host_devices(code: str, n: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=540, cwd=REPO)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


_SHARDED_SERVE_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro import configs
from repro.config import DistConfig, FlowRLConfig
from repro.core import schedulers
from repro.core.rollout import request_keys
from repro.distributed import data_mesh
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter
from repro.serving import ServingEngine

assert jax.local_device_count() == 4, jax.devices()
ARCH = configs.get_reduced("flux_dit")
FLOW = FlowRLConfig(num_steps=3, latent_tokens=8, latent_dim=8)
adapter = FlowAdapter(ARCH, FLOW, 512)
key = jax.random.PRNGKey(7)
params = params_lib.init(adapter.spec(), key, jnp.float32)
sched = schedulers.build("flow_sde", 0.7)
cond = jax.random.normal(jax.random.PRNGKey(1), (10, 4, 512), jnp.float32)

def build(mesh):
    return ServingEngine(adapter, sched, params, num_steps=3, max_batch=8,
                         mesh=mesh, cond_len=4)

single = build(None)
sharded = build(data_mesh(DistConfig(data_parallel=4)))
assert sharded.grid.sizes == (4, 8), sharded.grid.sizes   # dp-aligned
lat_1 = single.serve(cond, key)
lat_4 = sharded.serve(cond, key)
# THE acceptance property: per-request output is bit-identical across
# device layouts (keys shard with their requests; no axis-index folds)
np.testing.assert_array_equal(np.asarray(lat_1), np.asarray(lat_4))
# the remainder (10 = 8 + 2) rode a padded dp-aligned bucket on the mesh
assert sharded.stats["dispatches"] == {(8, 3): 1, (4, 3): 1}, \
    sharded.stats["dispatches"]
# trainer-path rollout equality as well (full Trajectory)
t1 = single.rollout(params, cond[:8], key)
t4 = sharded.rollout(params, cond[:8], key)
np.testing.assert_array_equal(np.asarray(t1.xs), np.asarray(t4.xs))
np.testing.assert_array_equal(np.asarray(t1.logps), np.asarray(t4.logps))
# and the sharded engine really placed work on all 4 devices
traj = sharded._fn(3)(params, cond[:8], request_keys(key, 8),
                      jnp.ones((3,), bool))
assert len(traj.cond.sharding.device_set) == 4, traj.cond.sharding
print("SHARDED-SERVE-OK")
"""


def test_sharded_serving_bit_identical_to_single_device_subprocess():
    """dist.data_parallel=4 serving (faked CPU host devices) returns
    bit-identical latents per request vs single-device — the serving
    acceptance criterion."""
    out = _run_with_host_devices(_SHARDED_SERVE_SCRIPT)
    assert "SHARDED-SERVE-OK" in out
