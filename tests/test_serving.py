"""repro.serving engine tests: bucket/step-tier policy, remainder/padded
batches, per-request determinism (the keyed-rollout invariant all batching
rests on), deadline-flush admission, priority classes + weighted-fair
multi-tenant dequeue, SLO deadlines, admission control with structured
retry-after backpressure, cond-cache behaviour, warmup, trainer opt-in,
sharded-vs-single-device bit-identity (4 faked CPU host devices, spawned
in a subprocess so the tier-1 environment stays single-device), and a
deterministic seeded fuzz harness over submit/poll/fetch/drain
interleavings (``REPRO_FUZZ_SEEDS`` scales the corpus; ``make fuzz-serve``
runs 200)."""
import functools
import gc
import json
import os
import random
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, registry
from repro.config import DistConfig, FlowRLConfig, OptimConfig, RewardSpec
from repro.core import schedulers
from repro.core.rollout import request_keys, rollout_keyed
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter
from repro.serving import (AdmissionConfig, BucketGrid, PriorityClass,
                           RetryAfter, ServingEngine, StepGrid,
                           default_buckets)

KEY = jax.random.PRNGKey(7)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

ARCH = configs.get_reduced("flux_dit")
FLOW = FlowRLConfig(num_steps=3, latent_tokens=8, latent_dim=8,
                    clip_range=0.2,
                    rewards=(RewardSpec("text_render", 1.0,
                             args={"latent_dim": 8, "latent_tokens": 8}),))
ADAPTER = FlowAdapter(ARCH, FLOW, 512)
PARAMS = params_lib.init(ADAPTER.spec(), KEY, jnp.float32)
SCHED = schedulers.build("flow_sde", 0.7)
COND = jax.random.normal(jax.random.PRNGKey(1), (7, 4, 512), jnp.float32)


class _Clock:
    """Injectable logical clock for deadline tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _engine(**kw):
    kw.setdefault("num_steps", FLOW.num_steps)
    kw.setdefault("max_batch", 4)
    kw.setdefault("cond_len", 4)
    return ServingEngine(ADAPTER, SCHED, kw.pop("params", PARAMS), **kw)


# ------------------------------------------------------------- bucket policy

def test_default_buckets_are_powers_of_two_up_to_max():
    assert default_buckets(8) == (1, 2, 4, 8)
    assert default_buckets(6) == (1, 2, 4, 6)
    assert default_buckets(1) == (1,)
    with pytest.raises(ValueError, match="max_batch"):
        default_buckets(0)


def test_bucket_grid_picks_smallest_covering_tier():
    g = BucketGrid(max_batch=8)
    assert [g.pick(n) for n in (1, 2, 3, 5, 8)] == [1, 2, 4, 8, 8]
    with pytest.raises(ValueError, match="exceed"):
        g.pick(9)
    with pytest.raises(ValueError, match="bucket"):
        g.pick(0)


def test_bucket_grid_dp_alignment():
    """Sharded serving needs equal per-device slices: tiers round up to
    multiples of dp and collapse duplicates."""
    g = BucketGrid(max_batch=8, dp=4)
    assert g.sizes == (4, 8)
    assert g.pick(1) == 4 and g.pick(5) == 8
    g = BucketGrid([3, 5, 6], dp=2)
    assert g.sizes == (4, 6)


def test_bucket_grid_alignment_never_raises_memory_cap():
    """max_batch is a memory bound: dp-alignment clamps DOWN to the
    largest dp multiple <= the requested cap (dp itself only when the cap
    is below one lane per device — the smallest batch a mesh can run)."""
    assert BucketGrid(max_batch=6, dp=4).sizes == (4,)
    assert BucketGrid(max_batch=11, dp=4).sizes == (4, 8)
    assert BucketGrid([3], dp=4).sizes == (4,)          # below one/device
    # explicit tiers above the cap are a config error, not a silent OOM
    with pytest.raises(ValueError, match="max_batch"):
        BucketGrid([16], max_batch=8)


def test_step_grid_admits_only_warmed_tiers():
    """The second compile-grid axis: num_steps outside the tier ladder is
    rejected at submit — an off-grid value would compile on the hot path,
    defeating the warmup contract."""
    g = StepGrid((4, 8), default=8)
    assert g.sizes == (4, 8)
    assert g.resolve(None) == 8 and g.resolve(4) == 4
    with pytest.raises(ValueError, match="step-tier grid"):
        g.resolve(6)
    # the default is always a member, even when tiers omit it
    assert StepGrid((2,), default=3).sizes == (2, 3)
    with pytest.raises(ValueError, match=">= 1"):
        StepGrid((0,), default=3)


# --------------------------------------------------- batch shape correctness

def test_remainder_batch_returns_exactly_n_outputs():
    """7 requests through max_batch=4 => one full bucket + a padded
    remainder; exactly 7 latents come back, in request order."""
    eng = _engine()
    lat = eng.serve(COND, KEY)
    assert lat.shape == (7, 8, 8)
    assert np.isfinite(np.asarray(lat)).all()
    stats = eng.stats
    assert stats["dispatches"] == {"b4/s3": 2}
    assert stats["padded_lanes"] == 1          # 3-request remainder in b=4
    # request order: row i is exactly the single-request serve of key i
    keys = request_keys(KEY, 7)
    eng2 = _engine()
    h = eng2.submit(cond=COND[5], key=keys[5])
    eng2.drain()
    np.testing.assert_array_equal(np.asarray(lat[5]),
                                  np.asarray(h.result()))


def test_serve_empty_request_list_returns_empty_batch():
    """Regression: serve([]) used to reach np.stack([]) and raise — an
    empty request list is a valid (if quiet) production input and must
    return a correctly-shaped (0, Lt, ld) array from either input form."""
    eng = _engine()
    lat = eng.serve([])
    assert lat.shape == (0, 8, 8) and lat.dtype == jnp.float32
    lat = eng.serve(np.zeros((0, 4, 512), np.float32), KEY)
    assert lat.shape == (0, 8, 8)
    assert eng.stats["requests"] == 0 and eng.stats["dispatches"] == {}


def test_serve_drives_queue_past_admission_bounds():
    """Regression: a synchronous serve() of more requests than
    max_inflight*capacity + the class depth bound used to raise RetryAfter
    from inside its submit loop (no result materializes during the loop,
    so in-flight slots never retire and the queue fills), abandoning the
    already-dispatched handles — serve() now drives its own queue on
    backpressure, so any N serves, bit-identically per request."""
    eng = _engine(max_inflight=1, admission=_admission())
    # bound before the fix: 1 inflight * capacity 4 + standard depth 6 = 10
    cond = jax.random.normal(jax.random.PRNGKey(3), (24, 4, 512),
                             jnp.float32)
    lat = eng.serve(cond, KEY)
    assert lat.shape == (24, 8, 8)
    assert eng.pending() == 0
    # per-request bit-identity with an unconstrained engine's serve
    lat2 = _engine().serve(cond, KEY)
    np.testing.assert_array_equal(np.asarray(lat), np.asarray(lat2))


def test_per_request_determinism_across_batching():
    """Same request key => bit-identical latent whatever bucket grid,
    max_batch, or batch mates it is served with."""
    lat_a = _engine(max_batch=4).serve(COND, KEY)
    lat_b = _engine(max_batch=2).serve(COND, KEY)
    lat_c = _engine(max_batch=8, buckets=(3, 7, 8)).serve(COND, KEY)
    np.testing.assert_array_equal(np.asarray(lat_a), np.asarray(lat_b))
    np.testing.assert_array_equal(np.asarray(lat_a), np.asarray(lat_c))
    # a permuted batch serves each request identically too
    perm = [3, 0, 6, 1, 5, 2, 4]
    keys = request_keys(KEY, 7)
    eng = _engine(max_batch=4)
    handles = [eng.submit(cond=COND[i], key=keys[i]) for i in perm]
    eng.drain()
    for j, i in enumerate(perm):
        np.testing.assert_array_equal(np.asarray(handles[j].result()),
                                      np.asarray(lat_a[i]))


def test_rollout_keyed_masked_steps_integrate_plain_flow():
    """With eta>0, an sde_mask=False step must follow step_ode (x - v·Δ),
    NOT the SDE drift mean (whose sigma^2/2t correction is nonzero even
    when the noise is masked off) — the MixGRPO ODE-window contract that
    `rollout` implements and attach_engine must preserve."""
    from repro.core.rollout import mix_sde_mask
    mask = mix_sde_mask(3, 2)                     # [SDE, SDE, ODE]
    keys = request_keys(KEY, 4)
    traj = rollout_keyed(ADAPTER, PARAMS, COND[:4], keys, SCHED, 3, mask)
    for j in range(3):
        tb = jnp.full((4,), traj.ts[j], jnp.float32)
        v = ADAPTER.velocity(PARAMS, traj.xs[j], tb, COND[:4])
        x_ode = SCHED.step_ode(v, traj.xs[j], traj.ts[j], traj.ts[j + 1])
        if bool(mask[j]):
            # stochastic step: departs from the plain flow, logps recorded
            assert not np.allclose(np.asarray(traj.xs[j + 1]),
                                   np.asarray(x_ode), atol=1e-5)
            assert (np.asarray(traj.logps[j]) != 0).all()
        else:
            np.testing.assert_allclose(np.asarray(traj.xs[j + 1]),
                                       np.asarray(x_ode),
                                       atol=1e-6, rtol=1e-6)
            np.testing.assert_array_equal(np.asarray(traj.logps[j]), 0.0)


def test_rollout_keyed_batch_composition_invariance():
    """The primitive underneath: any sub-batch of (cond, keys) rows yields
    bit-identical per-row trajectories."""
    keys = request_keys(KEY, 5)
    full = rollout_keyed(ADAPTER, PARAMS, COND[:5], keys, SCHED, 3)
    sub = rollout_keyed(ADAPTER, PARAMS, COND[1:4], keys[1:4], SCHED, 3)
    np.testing.assert_array_equal(np.asarray(full.xs[:, 1:4]),
                                  np.asarray(sub.xs))
    np.testing.assert_array_equal(np.asarray(full.logps[:, 1:4]),
                                  np.asarray(sub.logps))
    with pytest.raises(ValueError, match="keys"):
        rollout_keyed(ADAPTER, PARAMS, COND[:5], keys[:4], SCHED, 3)


# ----------------------------------------------------- admission & deadlines

def test_full_bucket_dispatches_immediately():
    """Continuous batching: a full bucket never waits for the deadline."""
    clk = _Clock()
    eng = _engine(deadline_s=1e9, clock=clk)
    keys = request_keys(KEY, 4)
    handles = [eng.submit(cond=COND[i], key=keys[i]) for i in range(4)]
    assert all(h.done for h in handles)        # dispatched at 4th submit
    assert eng.pending() == 0
    assert eng.stats["dispatches"] == {"b4/s3": 1}


def test_partial_bucket_waits_for_deadline_then_flushes():
    clk = _Clock()
    eng = _engine(deadline_s=0.5, clock=clk)
    keys = request_keys(KEY, 2)
    handles = [eng.submit(cond=COND[i], key=keys[i]) for i in range(2)]
    assert not any(h.done for h in handles) and eng.pending() == 2
    clk.t = 0.4
    assert eng.poll() == 0                     # deadline not reached
    assert eng.pending() == 2
    clk.t = 0.6
    assert eng.poll() == 2                     # oldest crossed the deadline
    assert all(h.done for h in handles)
    assert eng.stats["dispatches"] == {"b2/s3": 1}  # smallest covering tier
    with pytest.raises(RuntimeError, match="not been served"):
        _engine(clock=_Clock(), deadline_s=1e9) \
            .submit(cond=COND[0], key=keys[0]).result()


def test_drain_flushes_everything_regardless_of_deadline():
    clk = _Clock()
    eng = _engine(deadline_s=1e9, clock=clk)
    keys = request_keys(KEY, 3)
    handles = [eng.submit(cond=COND[i], key=keys[i]) for i in range(3)]
    assert eng.drain() == 3 and all(h.done for h in handles)


def test_num_steps_tiers_are_separate_buckets():
    eng = _engine(step_tiers=(2, 3))
    h3 = eng.submit(cond=COND[0], seed=0)                 # default 3 steps
    h2 = eng.submit(cond=COND[1], seed=1, num_steps=2)
    eng.drain()
    assert h3.result().shape == h2.result().shape == (8, 8)
    assert set(eng.stats["dispatches"]) == {"b1/s3", "b1/s2"}
    assert not np.array_equal(np.asarray(h3.result()),
                              np.asarray(h2.result()))


def test_submit_rejects_num_steps_outside_step_grid():
    """Regression (unbounded-recompile hole): an off-grid num_steps used
    to compile a fresh executable on the hot path — now it is rejected at
    submit, so steady state provably never compiles."""
    eng = _engine(step_tiers=(2, 3))
    with pytest.raises(ValueError, match="step-tier grid"):
        eng.submit(cond=COND[0], seed=0, num_steps=7)
    with pytest.raises(ValueError, match="step-tier grid"):
        eng.submit(cond=COND[0], seed=0, num_steps=0)
    assert eng.pending() == 0                  # nothing half-enqueued


def test_submit_rejects_cond_shape_outside_warmed_grid():
    """Regression (unbounded-recompile hole): cond was only checked for
    ndim == 2, so a request with a different Lc or cond_dim compiled per
    distinct shape in the hot path — now the exact warmed (cond_len,
    cond_dim) shape is enforced."""
    eng = _engine()                            # cond_len=4, cond_dim=512
    with pytest.raises(ValueError, match=r"\(4, 512\)"):
        eng.submit(cond=np.zeros((5, 512), np.float32), seed=0)   # wrong Lc
    with pytest.raises(ValueError, match=r"\(4, 512\)"):
        eng.submit(cond=np.zeros((4, 256), np.float32), seed=0)   # wrong D
    assert eng.pending() == 0


def test_auto_keys_do_not_collide_with_seeds_or_across_engines():
    """Regression: the auto key used to be PRNGKey(rid), which collided
    with a user submit(seed=rid) and repeated across engine instances —
    auto keys are now fold_in chains off a per-engine base key."""
    eng = _engine()
    h_auto = eng.submit(cond=COND[0])          # auto key, rid == 0
    h_seed = eng.submit(cond=COND[0], seed=h_auto.rid)
    eng.drain()
    assert not np.array_equal(np.asarray(h_auto.result()),
                              np.asarray(h_seed.result()))
    # a second engine's auto key for the same rid is a different stream
    eng2 = _engine()
    h_auto2 = eng2.submit(cond=COND[0])
    eng2.drain()
    assert h_auto2.rid == h_auto.rid
    assert not np.array_equal(np.asarray(h_auto.result()),
                              np.asarray(h_auto2.result()))
    # user-seeded submits stay reproducible across engines
    h_seed2 = eng2.submit(cond=COND[0], seed=0)
    eng2.drain()
    h_seed1 = eng.submit(cond=COND[0], seed=0)
    eng.drain()
    np.testing.assert_array_equal(np.asarray(h_seed1.result()),
                                  np.asarray(h_seed2.result()))


def test_auto_key_blocks_match_fold_in_chain():
    """Regression: auto keys used to fold on-device per submit (a blocking
    host<->device round-trip on the queue hot path) — they now come from
    host-side blocks, bit-identical to fold_in(base, rid) within and
    across block boundaries."""
    from repro.serving.engine import _AUTO_KEY_BLOCK
    eng = _engine()
    base = jnp.asarray(eng._base_key)
    for rid in (0, 1, _AUTO_KEY_BLOCK - 1, _AUTO_KEY_BLOCK,
                3 * _AUTO_KEY_BLOCK + 5):
        np.testing.assert_array_equal(
            np.asarray(eng._auto_key(rid)),
            np.asarray(jax.random.fold_in(base, rid)))


# ------------------------------------------- multi-tenant admission control

def _admission(**kw):
    kw.setdefault("classes", (
        PriorityClass("interactive", weight=4, max_depth=8, slo_s=0.3),
        PriorityClass("standard", weight=2, max_depth=6),
        PriorityClass("batch", weight=1, max_depth=5),
    ))
    return AdmissionConfig(**kw)


def test_admission_config_validation():
    with pytest.raises(ValueError, match="default_class"):
        AdmissionConfig(default_class="nope")
    with pytest.raises(ValueError, match="duplicate"):
        AdmissionConfig(classes=(PriorityClass("a"), PriorityClass("a")),
                        default_class="a")
    with pytest.raises(ValueError, match="weight"):
        PriorityClass("x", weight=0)
    with pytest.raises(ValueError, match="max_depth"):
        PriorityClass("x", max_depth=0)
    eng = _engine(admission=_admission())
    with pytest.raises(ValueError, match="unknown priority class"):
        eng.submit(cond=COND[0], seed=0, priority="platinum")
    with pytest.raises(ValueError, match="slo_s"):
        eng.submit(cond=COND[0], seed=0, slo_s=-1.0)
    with pytest.raises(ValueError, match="max_inflight"):
        _engine(max_inflight=0)


def test_over_capacity_submit_rejected_with_structured_retry_after():
    """THE admission acceptance criterion: once a priority class is at its
    depth bound, submit raises RetryAfter — a structured, JSON-ready
    rejection with a deterministic retry hint — instead of queueing
    unboundedly.  After a flush frees the queue, the retry succeeds."""
    clk = _Clock()
    eng = _engine(admission=_admission(), deadline_s=0.5, clock=clk,
                  max_inflight=1)
    # occupy the only in-flight slot so queues actually build up (the
    # handles must stay referenced: dropping them would retire the slot
    # via the GC-reclamation path)
    blockers = [eng.submit(cond=COND[i], seed=i) for i in range(4)]
    assert eng.stats["inflight"] == 1
    handles = [eng.submit(cond=COND[i % 7], seed=10 + i, priority="batch")
               for i in range(5)]              # batch max_depth == 5
    with pytest.raises(RetryAfter) as ei:
        eng.submit(cond=COND[0], seed=99, priority="batch")
    err = ei.value
    assert (err.priority, err.depth, err.limit) == ("batch", 5, 5)
    payload = err.to_json()
    assert json.loads(json.dumps(payload)) == payload
    assert payload["error"] == "over_capacity"
    # the hint is the earliest queued dispatch deadline: flush at t=0.5
    assert payload["retry_after_s"] == pytest.approx(0.5)
    # other classes are unaffected by batch's full queue
    eng.submit(cond=COND[0], seed=50, priority="interactive")
    assert eng.stats["priorities"]["batch"]["rejected"] == 1
    # reject-then-retry: the deadline flush frees the queue
    clk.t = 0.6
    eng.poll()
    assert eng.pending() == 0
    h = eng.submit(cond=COND[0], seed=99, priority="batch")
    clk.t = 2.0
    eng.poll()
    assert h.done and all(x.done for x in handles + blockers)


def test_weighted_fair_dequeue_across_tenants_and_classes():
    """With contention (in-flight slot occupied), the freed batch is
    filled by stride scheduling: interactive (weight 4) gets both its
    requests in, the backlogged batch tenant gets the remaining slots —
    but is NOT starved."""
    clk = _Clock()
    eng = _engine(admission=_admission(), deadline_s=1e9, clock=clk,
                  max_inflight=1)
    first = [eng.submit(cond=COND[i], seed=i) for i in range(4)]
    assert all(h.done for h in first)          # occupies the slot
    heavy = [eng.submit(cond=COND[i % 7], seed=10 + i, priority="batch",
                        tenant="miner") for i in range(5)]
    light = [eng.submit(cond=COND[i], seed=30 + i, priority="interactive",
                        tenant="human") for i in range(2)]
    assert eng.pending() == 7
    # fetching a result retires the in-flight slot -> one fair batch goes
    first[0].result()
    done_heavy = sum(h.done for h in heavy)
    done_light = sum(h.done for h in light)
    assert done_light == 2                     # weight-4 class never waits
    assert done_heavy == 2                     # and batch is not starved
    assert eng.stats["served_by_tenant"]["human"] == 2
    clk.t = 1e12
    eng.poll()
    assert all(h.done for h in heavy)


def test_slo_deadline_flushes_before_batching_deadline():
    """A request's dispatch deadline is min(flush deadline, SLO deadline):
    a tight SLO forces an earlier partial-bucket flush, and dispatches
    past the SLO are counted per class."""
    clk = _Clock()
    eng = _engine(admission=_admission(), deadline_s=0.5, clock=clk)
    h = eng.submit(cond=COND[0], seed=0, priority="interactive")  # slo 0.3
    clk.t = 0.2
    assert eng.poll() == 0 and not h.done
    clk.t = 0.35                               # past SLO, before flush ddl
    assert eng.poll() == 1 and h.done
    assert eng.stats["slo_misses"] == {"interactive": 1}
    # an explicit per-request SLO overrides the class default
    h2 = eng.submit(cond=COND[1], seed=1, priority="interactive",
                    slo_s=5.0)
    clk.t = 0.75                               # 0.4s elapsed < slo 5.0
    assert eng.poll() == 0 and not h2.done
    clk.t = 0.9                                # flush deadline (0.5) wins
    assert eng.poll() == 1 and h2.done
    assert eng.stats["slo_misses"] == {"interactive": 1}   # h2 met its SLO


def test_backpressure_bounds_inflight_and_retires_on_fetch():
    """max_inflight bounds dispatched-but-unfetched batches: full buckets
    queue while the window is full, and fetching a result opens the next
    dispatch (continuous batching under backpressure)."""
    clk = _Clock()
    eng = _engine(deadline_s=1e9, clock=clk, max_inflight=1)
    a = [eng.submit(cond=COND[i], seed=i) for i in range(4)]
    b = [eng.submit(cond=COND[i], seed=10 + i) for i in range(4)]
    assert all(h.done for h in a) and not any(h.done for h in b)
    assert eng.stats["inflight"] == 1 and eng.pending() == 4
    a[0].result()                              # retire -> pump
    assert all(h.done for h in b)
    assert eng.pending() == 0
    # drain ignores the window: a promise to finish beats the policy
    c = [eng.submit(cond=COND[i], seed=20 + i) for i in range(2)]
    assert eng.drain() == 2 and all(h.done for h in c)


def test_abandoned_handles_release_inflight_slots_on_gc():
    """Regression: an in-flight slot used to retire only inside result(),
    so handles abandoned after dispatch (client timeout/disconnect — there
    is no cancel API) consumed max_inflight forever, after which full
    buckets only ever moved via deadline flushes — the slot now retires on
    GC of the batch's result holder, whichever of fetch/GC comes first."""
    clk = _Clock()
    eng = _engine(deadline_s=1e9, clock=clk, max_inflight=1)
    abandoned = [eng.submit(cond=COND[i], seed=i) for i in range(4)]
    assert all(h.done for h in abandoned)
    assert eng.stats["inflight"] == 1
    queued = [eng.submit(cond=COND[i], seed=10 + i) for i in range(4)]
    assert not any(h.done for h in queued)     # window full, bucket queued
    del abandoned                              # client walked away
    gc.collect()
    # the freed slot pumped the queued full bucket immediately
    assert all(h.done for h in queued)
    assert eng.stats["inflight"] == 1
    queued[0].result()
    assert eng.stats["inflight"] == 0


def test_poll_deadline_flush_bounded_per_call():
    """Regression: deadline flushes bypass max_inflight, but used to do so
    unboundedly — a burst of expired deadlines (slow consumer + short
    slo_s) could materialize any number of in-flight device batches in a
    single poll, reintroducing the memory growth the backpressure window
    exists to prevent.  The emergency window is now capped at
    2*max_inflight dispatches per call; the backlog drains over
    successive polls."""
    clk = _Clock()
    eng = _engine(deadline_s=0.1, clock=clk, max_inflight=1)
    blocker = [eng.submit(cond=COND[i], seed=i) for i in range(4)]
    assert all(h.done for h in blocker) and eng.stats["inflight"] == 1
    burst = [eng.submit(cond=COND[i % 7], seed=100 + i) for i in range(12)]
    clk.t = 1.0                                # every burst request expired
    eng.poll()
    # exactly 2 * max_inflight = 2 emergency batches (capacity 4) went out
    assert sum(h.done for h in burst) == 8
    assert eng.stats["inflight"] == 3 and eng.pending() == 4
    eng.poll()                                 # the next poll drains the rest
    assert all(h.done for h in burst) and eng.pending() == 0


def test_stats_snapshot_is_json_serializable():
    """Regression: dispatches/compiled_shapes used tuple keys/values, so
    the health endpoint could not json.dumps the snapshot."""
    eng = _engine(step_tiers=(2, 3), admission=_admission())
    eng.warmup()
    eng.serve(COND, KEY)
    eng.submit(cond=COND[0], seed=0, num_steps=2, priority="interactive",
               tenant="acme")
    eng.drain()
    s = eng.stats
    round_trip = json.loads(json.dumps(s))
    assert round_trip == s
    assert s["dispatches"] == {"b4/s3": 2, "b1/s2": 1}
    assert set(s["warmed_shapes"]) >= {"b1/s2", "b4/s3"}
    assert s["priorities"]["interactive"]["admitted"] == 1
    assert s["served_by_tenant"] == {"default": 7, "acme": 1}
    assert s["step_tiers"] == [2, 3]


# ------------------------------------------------------------ warmup & cache

def test_warmup_pretraces_grid_so_serving_never_compiles():
    eng = _engine()
    report = eng.warmup()
    assert set(report) == {"b1/s3", "b2/s3", "b4/s3"}
    assert all(dt > 0 for dt in report.values())
    eng.serve(COND, KEY)
    stats = eng.stats
    assert stats["cold_dispatches"] == 0
    assert stats["warmup_s"] > 0
    # an un-warmed engine serving the same load compiles on the hot path
    # (both dispatches share the (4, 3) shape, so exactly one cold trace)
    cold = _engine()
    cold.serve(COND, KEY)
    assert cold.stats["cold_dispatches"] == 1


def test_warmup_covers_every_step_tier_by_default():
    """The provably-never-compiles contract: submit only admits (cond
    shape × step tier) combinations warmup pre-traced."""
    eng = _engine(step_tiers=(2, 3))
    report = eng.warmup()
    assert set(report) == {"b1/s2", "b2/s2", "b4/s2",
                           "b1/s3", "b2/s3", "b4/s3"}
    for steps in (2, 3):
        for i in range(5):
            eng.submit(cond=COND[i], seed=i, num_steps=steps)
    eng.drain()
    assert eng.stats["cold_dispatches"] == 0


def test_cond_cache_skips_encoder_for_repeat_prompts():
    from repro.core.preprocess import ConditionProvider
    provider = ConditionProvider(
        preprocessing=False,
        encoder_kw=dict(cond_dim=512, cond_len=4, vocab=256, hidden=32))
    eng = _engine(provider=provider)
    lat1 = eng.serve(["a fox", "a robot", "a fox"], KEY)
    cc = eng.stats["cond_cache"]
    assert cc == {"hits": 1, "misses": 2, "entries": 2}
    # same prompts + same base key again: all hits, identical latents
    lat2 = eng.serve(["a fox", "a robot", "a fox"], KEY)
    cc = eng.stats["cond_cache"]
    assert cc["hits"] == 4 and cc["misses"] == 2
    np.testing.assert_array_equal(np.asarray(lat1), np.asarray(lat2))


def test_cond_cache_lru_eviction():
    from repro.serving import CondCache
    c = CondCache(max_entries=2)
    c.put("a", np.zeros(1)); c.put("b", np.ones(1))
    assert c.get("a") is not None              # refresh "a"
    c.put("c", np.ones(1))                     # evicts "b" (LRU)
    assert c.get("b") is None and len(c) == 2
    assert c.get("a") is not None and c.get("c") is not None


def test_submit_validation():
    eng = _engine()
    with pytest.raises(ValueError, match="exactly one"):
        eng.submit()
    with pytest.raises(ValueError, match="exactly one"):
        eng.submit(cond=COND[0], prompt="both")
    with pytest.raises(ValueError, match="Lc, cond_dim"):
        eng.submit(cond=COND)                  # batch where a row belongs
    with pytest.raises(ValueError, match="ConditionProvider"):
        eng.submit(prompt="no provider attached")


# ------------------------------------------------------------ trainer opt-in

def test_trainer_attach_engine_end_to_end():
    """Online RL sampling through the serving engine: same Trajectory
    contract, per-request keyed, finite metrics through a full step."""
    tr = registry.build("trainer", "flow_grpo", ARCH, FLOW,
                        OptimConfig(lr=1e-3, total_steps=8, warmup_steps=2),
                        key=KEY, dtype=jnp.float32, dist=DistConfig())
    eng = ServingEngine.for_trainer(tr, max_batch=8, cond_len=4)
    tr.attach_engine(eng)
    cond = jax.random.normal(jax.random.PRNGKey(2), (3, 4, 512), jnp.float32)
    m = tr.step(cond, KEY, it=0)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["reward_mean"]))
    # 3 prompts x group 8 = 24 rollouts -> 3 capacity-8 chunks, no padding
    assert eng.stats["dispatches"] == {"b8/s3": 3}
    # the engine rollout is the keyed primitive (jitted on both sides).
    # B=24-in-one-call vs three B=8 chunks may differ by reduction-order
    # ulps when XLA retiles matmuls at the larger shape (observed only
    # under the 4-faked-device flag), so this cross-shape check is
    # allclose; the *equal-shape* bit-identity contracts are asserted
    # exactly elsewhere in this file.
    traj = tr.sample(tr.state.params, cond, KEY, it=0)
    keys = request_keys(KEY, 24)
    from repro.core.rollout import group_repeat
    direct = jax.jit(lambda p, c, k: rollout_keyed(
        ADAPTER, p, c, k, tr.scheduler, 3))(
            tr.state.params, group_repeat(cond, 8), keys)
    np.testing.assert_allclose(np.asarray(traj.xs),
                               np.asarray(direct.xs),
                               atol=1e-5, rtol=1e-3)
    tr.attach_engine(None)                     # detach restores jit path
    traj2 = tr.sample(tr.state.params, cond, KEY, it=0)
    assert traj2.xs.shape == traj.xs.shape


def test_attach_engine_rejects_mismatched_components():
    """A foreign scheduler would make the update recompute log-probs under
    a DIFFERENT transition density than the one sampled — silently wrong
    ratios — so attach validates num_steps, scheduler, and mesh."""
    tr = registry.build("trainer", "flow_grpo", ARCH, FLOW,
                        OptimConfig(total_steps=8), key=KEY,
                        dtype=jnp.float32)
    with pytest.raises(ValueError, match="num_steps"):
        tr.attach_engine(_engine(num_steps=5))
    wrong_sched = ServingEngine(ADAPTER, schedulers.build("dance_sde", 0.3),
                                PARAMS, num_steps=FLOW.num_steps,
                                cond_len=4)
    with pytest.raises(ValueError, match="scheduler"):
        tr.attach_engine(wrong_sched)
    wrong_eta = ServingEngine(ADAPTER, schedulers.build("flow_sde", 0.1),
                              PARAMS, num_steps=FLOW.num_steps, cond_len=4)
    with pytest.raises(ValueError, match="scheduler"):
        tr.attach_engine(wrong_eta)


def test_engine_rollout_chunking_matches_single_dispatch():
    """B > capacity runs in capacity slices; the concatenated Trajectory is
    bit-identical to one unchunked keyed rollout."""
    eng = _engine(max_batch=4)
    cond = COND[:6]
    traj = eng.rollout(PARAMS, cond, KEY)
    direct = jax.jit(lambda p, c, k: rollout_keyed(
        ADAPTER, p, c, k, SCHED, 3))(PARAMS, cond, request_keys(KEY, 6))
    np.testing.assert_array_equal(np.asarray(traj.xs),
                                  np.asarray(direct.xs))
    np.testing.assert_array_equal(np.asarray(traj.logps),
                                  np.asarray(direct.logps))
    np.testing.assert_array_equal(np.asarray(traj.cond),
                                  np.asarray(direct.cond))
    # 6 = 4 + 2 -> second chunk rides the b2 tier, no padding at all
    assert eng.stats["dispatches"] == {"b4/s3": 1, "b2/s3": 1}
    assert eng.stats["padded_lanes"] == 0


# --------------------------------------------------------- fuzz harness
#
# A deterministic seeded fuzzer over submit/poll/fetch/drain interleavings
# against ONE warmed engine (shared module-scoped state keeps the compile
# cache hot, exactly like a long-lived production process).  Invariants
# checked after EVERY op and at episode end:
#   * bounded queues: per-class depth never exceeds its admission limit
#   * no starvation: polling clears every expired request in a bounded
#     number of calls (each poll's emergency flush window is capped at
#     2*max_inflight dispatches, so one call may leave a burst's tail)
#   * per-request bit-identity: results equal a direct keyed rollout
#   * cold_dispatches == 0 across the whole fuzzed load (post-warmup)
# REPRO_FUZZ_SEEDS sizes the corpus (default 25 in tier-1; `make
# fuzz-serve` runs 200).

FUZZ_SEEDS = int(os.environ.get("REPRO_FUZZ_SEEDS", "25"))
FUZZ_TENANTS = ("acme", "heavy", "solo")
FUZZ_CLASSES = ("interactive", "standard", "batch", None)


@pytest.fixture(scope="module")
def fuzz_env():
    clk = _Clock()
    eng = _engine(
        step_tiers=(2, 3), deadline_s=0.5, max_inflight=2, clock=clk,
        admission=_admission(tenant_weights=(("heavy", 3),)))
    eng.warmup()
    direct = {
        s: jax.jit(functools.partial(
            lambda p, c, k, steps: rollout_keyed(
                ADAPTER, p, c, k, SCHED, steps).x0, steps=s))
        for s in (2, 3)}
    return eng, clk, direct


def _check_invariants(eng):
    snap = eng.admission.snapshot()
    for name, row in snap.items():
        assert row["depth"] <= row["limit"], \
            f"queue bound violated for {name}: {row}"
    assert eng.pending() == sum(r["depth"] for r in snap.values())


def _fuzz_episode(eng, clk, direct, seed):
    rng = random.Random(seed)
    live = []                                 # (handle, cond_idx, steps)
    rejections = 0
    for _ in range(rng.randint(6, 14)):
        op = rng.random()
        if op < 0.62:
            i = rng.randrange(7)
            steps = rng.choice((2, 3, None))
            try:
                h = eng.submit(
                    cond=COND[i], seed=rng.randrange(1 << 30),
                    num_steps=steps, tenant=rng.choice(FUZZ_TENANTS),
                    priority=rng.choice(FUZZ_CLASSES),
                    slo_s=rng.choice((None, 0.2, 0.8)))
                live.append((h, i, steps or 3))
            except RetryAfter as e:
                rejections += 1
                payload = e.to_json()
                assert payload["error"] == "over_capacity"
                assert payload["depth"] >= payload["limit"]
                assert payload["retry_after_s"] >= 0
        elif op < 0.88:
            clk.t += rng.choice((0.0, 0.1, 0.3, 0.6))
            eng.poll()
            # no starvation: each poll's emergency flush window is
            # bounded, so the *sequence* of polls must clear every
            # expired request, each call making progress
            polls = 1
            while any(eng.admission.has_expired(s, clk.t)
                      for s in eng.admission.tiers()):
                assert eng.poll() > 0, "expired request starved"
                polls += 1
                assert polls <= 64, "deadline backlog never drained"
        else:
            done = [h for h, _, _ in live if h.done]
            if done:
                rng.choice(done).result()      # retires in-flight slots
        _check_invariants(eng)
    clk.t += 1.0
    eng.drain()
    assert eng.pending() == 0
    assert all(h.done for h, _, _ in live), "request starved to drain"
    # fetch everything: materializing retires every in-flight slot, so the
    # backpressure window is provably clean between episodes
    for h, _, _ in live:
        h.result()
    assert eng.stats["inflight"] == 0
    # per-request bit-identity to a direct keyed rollout of (cond, key)
    for h, i, steps in rng.sample(live, min(3, len(live))):
        want = direct[steps](PARAMS, COND[i:i + 1],
                             np.asarray(h.key)[None])
        np.testing.assert_array_equal(np.asarray(h.result()),
                                      np.asarray(want)[0])
    return rejections


@pytest.mark.parametrize("seed", range(FUZZ_SEEDS))
def test_fuzz_serving_interleavings(fuzz_env, seed):
    eng, clk, direct = fuzz_env
    _fuzz_episode(eng, clk, direct, seed)


def test_fuzz_corpus_deadline_flush_races_full_bucket(fuzz_env):
    """Known-tricky interleaving: requests already past their deadline
    when a submit completes the bucket — the full-bucket dispatch at
    submit must win (each request served exactly once), and the following
    poll must find nothing left to flush."""
    eng, clk, direct = fuzz_env
    before = eng.stats["requests"]
    h = [eng.submit(cond=COND[i], seed=1000 + i) for i in range(3)]
    clk.t += 2.0                               # all three now expired
    h.append(eng.submit(cond=COND[3], seed=1003))   # completes the bucket
    assert all(x.done for x in h)              # dispatched at submit
    assert eng.poll() == 0 and eng.pending() == 0
    assert eng.stats["requests"] == before + 4
    want = direct[3](PARAMS, COND[0:1], np.asarray(h[0].key)[None])
    np.testing.assert_array_equal(np.asarray(h[0].result()),
                                  np.asarray(want)[0])


def test_fuzz_corpus_mixed_priorities_equal_arrival(fuzz_env):
    """Known-tricky interleaving: one request per class in the same clock
    tick; the deadline flush batches them together (same steps tier) and
    every class is served — priority orders contention, it never drops."""
    eng, clk, direct = fuzz_env
    h = [eng.submit(cond=COND[i], seed=2000 + i, priority=p)
         for i, p in enumerate(("interactive", "standard", "batch"))]
    assert not any(x.done for x in h)
    clk.t += 0.31                              # interactive SLO (0.3) first
    eng.poll()
    assert all(x.done for x in h)              # one b4 batch took all three
    for i, x in enumerate(h):
        want = direct[3](PARAMS, COND[i:i + 1], np.asarray(x.key)[None])
        np.testing.assert_array_equal(np.asarray(x.result()),
                                      np.asarray(want)[0])


def test_fuzz_corpus_reject_then_retry(fuzz_env):
    """Known-tricky interleaving: fill a class to its bound while the
    in-flight window is saturated, get the structured rejection, flush,
    and verify the retried submit serves bit-identically."""
    eng, clk, direct = fuzz_env
    clk.t += 10.0                              # quiesce prior deadlines
    eng.drain()
    blocker = []
    while eng.stats["inflight"] < eng.max_inflight:
        blocker += [eng.submit(cond=COND[i], seed=3000 + i,
                               priority="standard") for i in range(4)]
    queued = [eng.submit(cond=COND[i % 7], seed=3100 + i, priority="batch")
              for i in range(5)]               # batch max_depth == 5
    with pytest.raises(RetryAfter) as ei:
        eng.submit(cond=COND[0], seed=3200, priority="batch")
    clk.t += ei.value.retry_after_s + 1e-3     # honor the hint
    eng.poll()
    retry = eng.submit(cond=COND[0], seed=3200, priority="batch")
    clk.t += 1.0
    eng.poll()
    assert retry.done and all(x.done for x in queued + blocker)
    want = direct[3](PARAMS, COND[0:1], np.asarray(retry.key)[None])
    np.testing.assert_array_equal(np.asarray(retry.result()),
                                  np.asarray(want)[0])


def test_fuzz_load_never_compiled(fuzz_env):
    """Runs after the whole corpus (definition order): the entire fuzzed
    load — every interleaving, tier mix, and tenant mix — hit only warmed
    shapes, and the final stats snapshot still serializes."""
    eng, _, _ = fuzz_env
    assert eng.stats["cold_dispatches"] == 0
    assert json.loads(json.dumps(eng.stats)) == eng.stats


# ------------------------------------------------- multi-device (subprocess)

def _run_with_host_devices(code: str, n: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=540, cwd=REPO)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


_SHARDED_SERVE_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro import configs
from repro.config import DistConfig, FlowRLConfig
from repro.core import schedulers
from repro.core.rollout import request_keys
from repro.distributed import data_mesh
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter
from repro.serving import ServingEngine

assert jax.local_device_count() == 4, jax.devices()
ARCH = configs.get_reduced("flux_dit")
FLOW = FlowRLConfig(num_steps=3, latent_tokens=8, latent_dim=8)
adapter = FlowAdapter(ARCH, FLOW, 512)
key = jax.random.PRNGKey(7)
params = params_lib.init(adapter.spec(), key, jnp.float32)
sched = schedulers.build("flow_sde", 0.7)
cond = jax.random.normal(jax.random.PRNGKey(1), (10, 4, 512), jnp.float32)

def build(mesh):
    return ServingEngine(adapter, sched, params, num_steps=3, max_batch=8,
                         mesh=mesh, cond_len=4)

single = build(None)
sharded = build(data_mesh(DistConfig(data_parallel=4)))
assert sharded.grid.sizes == (4, 8), sharded.grid.sizes   # dp-aligned
lat_1 = single.serve(cond, key)
lat_4 = sharded.serve(cond, key)
# THE acceptance property: per-request output is bit-identical across
# device layouts (keys shard with their requests; no axis-index folds)
np.testing.assert_array_equal(np.asarray(lat_1), np.asarray(lat_4))
# the remainder (10 = 8 + 2) rode a padded dp-aligned bucket on the mesh
assert sharded.stats["dispatches"] == {"b8/s3": 1, "b4/s3": 1}, \
    sharded.stats["dispatches"]
# trainer-path rollout equality as well (full Trajectory)
t1 = single.rollout(params, cond[:8], key)
t4 = sharded.rollout(params, cond[:8], key)
np.testing.assert_array_equal(np.asarray(t1.xs), np.asarray(t4.xs))
np.testing.assert_array_equal(np.asarray(t1.logps), np.asarray(t4.logps))
# and the sharded engine really placed work on all 4 devices
traj = sharded._fn(3)(params, cond[:8], request_keys(key, 8),
                      jnp.ones((3,), bool))
assert len(traj.cond.sharding.device_set) == 4, traj.cond.sharding
print("SHARDED-SERVE-OK")
"""


def test_sharded_serving_bit_identical_to_single_device_subprocess():
    """dist.data_parallel=4 serving (faked CPU host devices) returns
    bit-identical latents per request vs single-device — the serving
    acceptance criterion."""
    out = _run_with_host_devices(_SHARDED_SERVE_SCRIPT)
    assert "SHARDED-SERVE-OK" in out
