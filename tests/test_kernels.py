"""Per-kernel shape/dtype sweeps against the pure-jnp oracles (interpret
mode executes the kernel body in Python on CPU — assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.grpo_loss import grpo_loss
from repro.kernels.sde_step import sde_step
from repro.kernels.ssd_scan import ssd_scan

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("B,Sq,Sk,H,K,D", [
    (2, 128, 128, 4, 2, 64),
    (1, 256, 256, 2, 1, 32),
    (2, 128, 128, 4, 4, 128),
    (1, 512, 512, 8, 2, 64),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Sq, Sk, H, K, D, causal, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, K, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, K, D), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               want.astype(jnp.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,L,H,P,N,Q", [
    (2, 128, 2, 32, 64, 32),
    (1, 256, 4, 64, 128, 128),
    (3, 64, 1, 16, 32, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan(B, L, H, P, N, Q, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (B, L, H, P), jnp.float32).astype(dtype)
    dt = (jax.nn.softplus(jax.random.normal(ks[1], (B, L, H))) * 0.5)
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = (jax.random.normal(ks[3], (B, L, N)) * 0.5).astype(dtype)
    cm = (jax.random.normal(ks[4], (B, L, N)) * 0.5).astype(dtype)
    y, hT = ssd_scan(x, dt, a, bm, cm, chunk=Q, interpret=True)
    yr, hr = ref.ssd_scan_ref(x, dt, a, bm, cm)
    tol = 5e-3 if dtype == jnp.float32 else 8e-2
    np.testing.assert_allclose(y.astype(jnp.float32),
                               yr.astype(jnp.float32), atol=tol, rtol=0.1)
    np.testing.assert_allclose(hT, hr, atol=tol, rtol=0.1)


@pytest.mark.parametrize("B,Lt,ld", [(2, 8, 4), (4, 64, 16), (1, 16, 8)])
@pytest.mark.parametrize("eta", [0.3, 0.7])
@pytest.mark.parametrize("t,t_next", [(0.9, 0.8), (0.5, 0.4), (0.2, 0.1)])
def test_sde_step(B, Lt, ld, eta, t, t_next):
    ks = jax.random.split(KEY, 3)
    v = jax.random.normal(ks[0], (B, Lt, ld))
    x = jax.random.normal(ks[1], (B, Lt, ld))
    eps = jax.random.normal(ks[2], (B, Lt, ld))
    xn, lp = sde_step(v, x, eps, t, t_next, eta=eta, interpret=True)
    xr, lr = ref.sde_step_ref(v, x, t, t_next, eps, eta=eta)
    np.testing.assert_allclose(xn, xr, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(lp, lr, atol=1e-3, rtol=1e-5)


@pytest.mark.parametrize("B", [7, 64, 1031])
@pytest.mark.parametrize("clip", [0.1, 0.3])
@pytest.mark.parametrize("guard", [False, True])
def test_grpo_loss(B, clip, guard):
    ks = jax.random.split(KEY, 3)
    lpn = jax.random.normal(ks[0], (B,)) * 0.05
    lpo = jax.random.normal(ks[1], (B,)) * 0.05
    adv = jax.random.normal(ks[2], (B,))
    rm = jnp.exp(jnp.clip(lpn - lpo, -20, 20)).mean()
    loss, frac = grpo_loss(lpn, lpo, adv, rm, clip=clip, guard=guard,
                           interpret=True)
    lref, fref = ref.grpo_loss_ref(lpn, lpo, adv, clip=clip, guard=guard)
    np.testing.assert_allclose(loss, lref, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(frac, fref, atol=0)


def test_kernel_matches_model_attention_path():
    """The kernel and the model's chunked-jnp attention agree (the dispatch
    layer can swap them freely)."""
    from repro.models.layers import attention_chunked
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    k = jax.random.normal(ks[1], (2, 256, 2, 64))
    v = jax.random.normal(ks[2], (2, 256, 2, 64))
    a = flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                        interpret=True)
    b = attention_chunked(q, k, v, causal=True, chunk_q=64)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=1e-4)


def test_trainer_kernel_path_equivalence(monkeypatch):
    """The GRPO trainer produces identical losses/gradients whether the SDE
    step + GRPO loss run through the Pallas kernels (interpret mode) or the
    jnp reference path — the dispatch layer is behaviour-preserving."""
    import os
    from repro import configs, registry
    from repro.config import FlowRLConfig, OptimConfig, RewardSpec
    key = jax.random.PRNGKey(0)
    arch = configs.get_reduced("flux_dit")
    flow = FlowRLConfig(
        num_steps=3, group_size=2, latent_tokens=8, latent_dim=8,
        rewards=(RewardSpec("text_render", 1.0,
                            args={"latent_dim": 8, "latent_tokens": 8}),))
    opt = OptimConfig(total_steps=4)
    cond = jax.random.normal(key, (2, 4, 512))
    results = {}
    for mode in ("off", "interpret"):
        monkeypatch.setenv("REPRO_PALLAS", mode)
        tr = registry.build("trainer", "flow_grpo", arch, flow, opt, key=key)
        for it in range(2):
            m = tr.step(cond, key, it=it)
        results[mode] = (float(m["loss"]), float(m["reward_mean"]),
                         float(m["grad_norm"]))
    np.testing.assert_allclose(results["off"], results["interpret"],
                               atol=2e-3)


class TestOpsDispatchEquivalence:
    """Every ``kernels/ops.py`` wrapper, exercised THROUGH the dispatch
    layer: with REPRO_PALLAS=interpret the Pallas body must reproduce the
    ``kernels/ref.py`` oracle the ``off`` mode would have returned — the
    dispatch decision can never change results."""

    def _ops(self, monkeypatch, mode):
        monkeypatch.setenv("REPRO_PALLAS", mode)
        from repro.kernels import ops
        assert ops.pallas_enabled() == (mode != "off")
        return ops

    def test_flash_attention_wrapper(self, monkeypatch):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (2, 128, 4, 32))
        k = jax.random.normal(ks[1], (2, 128, 2, 32))
        v = jax.random.normal(ks[2], (2, 128, 2, 32))
        for kw in ({"causal": True}, {"causal": False},
                   {"causal": True, "window": 64}):
            got = self._ops(monkeypatch, "interpret").flash_attention(
                q, k, v, **kw)
            want = self._ops(monkeypatch, "off").flash_attention(q, k, v,
                                                                 **kw)
            np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)

    def test_ssd_scan_wrapper(self, monkeypatch):
        ks = jax.random.split(KEY, 5)
        x = jax.random.normal(ks[0], (2, 128, 2, 16))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (2, 128, 2))) * 0.5
        a = -jnp.exp(jax.random.normal(ks[2], (2,)) * 0.3)
        bm = jax.random.normal(ks[3], (2, 128, 32)) * 0.5
        cm = jax.random.normal(ks[4], (2, 128, 32)) * 0.5
        y_i, h_i = self._ops(monkeypatch, "interpret").ssd_scan(
            x, dt, a, bm, cm, chunk=32)
        y_r, h_r = self._ops(monkeypatch, "off").ssd_scan(x, dt, a, bm, cm,
                                                          chunk=32)
        np.testing.assert_allclose(y_i, y_r, atol=5e-3, rtol=0.1)
        np.testing.assert_allclose(h_i, h_r, atol=5e-3, rtol=0.1)

    def test_sde_step_wrapper(self, monkeypatch):
        ks = jax.random.split(KEY, 3)
        v = jax.random.normal(ks[0], (4, 16, 8))
        x = jax.random.normal(ks[1], (4, 16, 8))
        eps = jax.random.normal(ks[2], (4, 16, 8))
        for t, t_next, eta in ((0.9, 0.8, 0.7), (0.3, 0.2, 0.3)):
            xn_i, lp_i = self._ops(monkeypatch, "interpret").sde_step(
                v, x, eps, t, t_next, eta=eta)
            xn_r, lp_r = self._ops(monkeypatch, "off").sde_step(
                v, x, eps, t, t_next, eta=eta)
            np.testing.assert_allclose(xn_i, xn_r, atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(lp_i, lp_r, atol=1e-3, rtol=1e-5)

    @pytest.mark.parametrize("guard", [False, True])
    def test_grpo_loss_wrapper(self, monkeypatch, guard):
        ks = jax.random.split(KEY, 3)
        lpn = jax.random.normal(ks[0], (64,)) * 0.05
        lpo = jax.random.normal(ks[1], (64,)) * 0.05
        adv = jax.random.normal(ks[2], (64,))
        rm = jnp.exp(jnp.clip(lpn - lpo, -20, 20)).mean()
        l_i, f_i = self._ops(monkeypatch, "interpret").grpo_loss(
            lpn, lpo, adv, rm, clip=0.2, guard=guard)
        l_r, f_r = self._ops(monkeypatch, "off").grpo_loss(
            lpn, lpo, adv, rm, clip=0.2, guard=guard)
        np.testing.assert_allclose(l_i, l_r, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(f_i, f_r, atol=0)

    def test_grpo_loss_trainable_wrapper(self, monkeypatch):
        """Value, clip-fraction metric, AND gradient agree across dispatch
        modes (the trainer differentiates through this wrapper)."""
        ks = jax.random.split(KEY, 3)
        lpn = jax.random.normal(ks[0], (48,)) * 0.1
        lpo = jax.random.normal(ks[1], (48,)) * 0.1
        adv = jax.random.normal(ks[2], (48,))

        def run(mode):
            ops = self._ops(monkeypatch, mode)

            def scalar_loss(lpn_):
                loss, frac = ops.grpo_loss_trainable(lpn_, lpo, adv,
                                                     clip=0.2)
                return loss.sum(), frac

            (val, frac), grad = jax.value_and_grad(
                scalar_loss, has_aux=True)(lpn)
            return val, frac, grad

        v_i, f_i, g_i = run("interpret")
        v_r, f_r, g_r = run("off")
        np.testing.assert_allclose(v_i, v_r, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(f_i, f_r, atol=0)
        np.testing.assert_allclose(g_i, g_r, atol=1e-5, rtol=1e-4)

    def test_keyed_rollout_dispatch_modes_agree(self, monkeypatch):
        """The serving engine's rollout (rollout_keyed -> step_with_eps)
        dispatches flow_sde steps through the fused sde_step kernel: the
        production serving path must be mode-invariant too."""
        from repro import configs
        from repro.config import FlowRLConfig
        from repro.core import schedulers
        from repro.core.rollout import request_keys, rollout_keyed
        from repro.models import params as params_lib
        from repro.models.flow import FlowAdapter
        arch = configs.get_reduced("flux_dit")
        flow = FlowRLConfig(num_steps=3, latent_tokens=8, latent_dim=8)
        adapter = FlowAdapter(arch, flow, 512)
        params = params_lib.init(adapter.spec(), KEY, jnp.float32)
        sched = schedulers.build("flow_sde", 0.7)
        cond = jax.random.normal(KEY, (4, 4, 512))
        keys = request_keys(KEY, 4)
        out = {}
        for mode in ("off", "interpret"):
            monkeypatch.setenv("REPRO_PALLAS", mode)
            out[mode] = rollout_keyed(adapter, params, cond, keys, sched, 3)
        np.testing.assert_allclose(out["off"].xs, out["interpret"].xs,
                                   atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(out["off"].logps, out["interpret"].logps,
                                   atol=1e-3, rtol=1e-5)

    def test_every_public_wrapper_is_covered(self):
        """Fail when a new ops.py wrapper lands without an equivalence case
        in this class (the gap this suite exists to close)."""
        import inspect
        from repro.kernels import ops
        wrappers = {n for n, f in vars(ops).items()
                    if inspect.isfunction(f) and not n.startswith("_")
                    and f.__module__ == "repro.kernels.ops"
                    and n not in ("pallas_enabled",)}
        covered = {n[len("test_"):-len("_wrapper")]
                   for n in dir(type(self))
                   if n.startswith("test_") and n.endswith("_wrapper")}
        assert wrappers <= covered, \
            f"ops wrappers without dispatch-equivalence tests: " \
            f"{sorted(wrappers - covered)}"


def test_grpo_loss_diff_gradient():
    """custom_vjp of the fused kernel matches autodiff of the jnp loss."""
    from repro.kernels.grpo_loss import grpo_loss_diff
    ks = jax.random.split(KEY, 3)
    lpn = jax.random.normal(ks[0], (32,)) * 0.1
    lpo = jax.random.normal(ks[1], (32,)) * 0.1
    adv = jax.random.normal(ks[2], (32,))

    def jnp_loss(lpn):
        loss, _ = ref.grpo_loss_ref(lpn, lpo, adv, clip=0.2)
        return loss.sum()

    def kern_loss(lpn):
        return grpo_loss_diff(lpn, lpo, adv, 0.2, True).sum()

    g_ref = jax.grad(jnp_loss)(lpn)
    g_kern = jax.grad(kern_loss)(lpn)
    np.testing.assert_allclose(g_kern, g_ref, atol=1e-5, rtol=1e-4)
