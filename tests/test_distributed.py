"""repro.distributed tests: gradient-accumulation microbatching equivalence,
batch/device validation, 2-D (data × model) mesh axis resolution and
PartitionPlan layouts, and multi-device (4 faked CPU host devices, spawned
in subprocesses so the single-device tier-1 environment stays untouched)
numerical equivalence of sharded vs single-device training — including
dp=2×mp=2 vs single-device for all four trainer families and checkpoint
portability across mesh layouts."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, distributed, registry
from repro.config import DistConfig, FlowRLConfig, OptimConfig, RewardSpec

KEY = jax.random.PRNGKey(3)

TINY_FLOW = FlowRLConfig(
    num_steps=3, group_size=4, latent_tokens=8, latent_dim=8,
    clip_range=0.2,
    rewards=(RewardSpec("text_render", 1.0,
                        args={"latent_dim": 8, "latent_tokens": 8}),))
TINY_OPT = OptimConfig(lr=1e-3, total_steps=20, warmup_steps=2)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _build(tname="flow_grpo", dist=None, dtype=jnp.float32):
    cfg = configs.get_reduced("flux_dit")
    return registry.build("trainer", tname, cfg, TINY_FLOW, TINY_OPT,
                          key=KEY, dtype=dtype, dist=dist)


# ------------------------------------------------------------- microbatching

def test_microbatch_grads_match_full_batch():
    """k-chunk gradient accumulation equals the full-batch gradient on the
    jnp path.  Most leaves are bit-exact; a few differ only in f32 summation
    order (XLA reduces the full batch in one tree, the accumulator adds k
    partial sums), so the assertion is allclose at float32 resolution."""
    tr = _build()
    cond = jax.random.normal(KEY, (4, 4, 512), jnp.float32)
    traj = tr.sample(tr.state.params, cond, KEY, it=0)
    _, adv, _ = tr._rewards_jit(traj.x0, {"cond": traj.cond})

    vg = jax.jit(lambda p, t, a: jax.value_and_grad(
        tr.loss_fn, has_aux=True)(p, t, a, KEY))
    (loss_full, _), grads_full = vg(tr.state.params, traj, adv)
    for k in (2, 4):
        acc = jax.jit(lambda p, t, a, k=k: distributed.accumulated_value_and_grad(
            tr.loss_fn, p, t, a, KEY, (), k))
        (loss_k, _), grads_k = acc(tr.state.params, traj, adv)
        np.testing.assert_allclose(float(loss_k), float(loss_full),
                                   rtol=0, atol=1e-7)
        for gf, gk in zip(jax.tree.leaves(grads_full),
                          jax.tree.leaves(grads_k)):
            np.testing.assert_allclose(np.asarray(gk), np.asarray(gf),
                                       rtol=1e-4, atol=1e-6)


def test_microbatch_full_update_step_equivalent():
    """End-to-end: a trainer with dist.microbatch=2 produces the same params
    trajectory as the full-batch trainer (same keys, same data)."""
    t_full = _build()
    t_mb = _build(dist=DistConfig(microbatch=2))
    cond = jax.random.normal(KEY, (2, 4, 512), jnp.float32)
    for it in range(2):
        m_full = t_full.step(cond, KEY, it=it)
        m_mb = t_mb.step(cond, KEY, it=it)
        # the GRPO loss is a cancellation residue of ~0 at rollout params,
        # so compare absolutely at f32 cancellation noise scale
        np.testing.assert_allclose(float(m_mb["loss"]), float(m_full["loss"]),
                                   rtol=0, atol=1e-5)
    # AdamW amplifies reduction-order grad noise where vhat ~ 0 (the update
    # m/sqrt(v) is sign-like), so params get a looser absolute band than the
    # raw gradients above: ~2.5e-5 observed on 0.01% of elements at lr=1e-3
    for a, b in zip(jax.tree.leaves(t_full.state.params),
                    jax.tree.leaves(t_mb.state.params)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=1e-3, atol=1e-4)


def test_microbatch_key_consuming_loss_steps():
    """NFT's loss draws timesteps/noise from the key; each chunk must get an
    independent fold of it (statistical, not numeric, equivalence)."""
    tr = _build("nft", dist=DistConfig(microbatch=2))
    cond = jax.random.normal(KEY, (2, 4, 512), jnp.float32)
    m = tr.step(cond, KEY, it=0)
    assert np.isfinite(float(m["loss"])) and np.isfinite(float(m["vel_err"]))


def test_microbatch_indivisible_batch_raises():
    tr = _build(dist=DistConfig(microbatch=3))
    cond = jax.random.normal(KEY, (2, 4, 512), jnp.float32)   # B = 8
    with pytest.raises(ValueError, match=r"8.*microbatch.*3"):
        tr.step(cond, KEY, it=0)


def test_negative_microbatch_rejected_at_construction():
    with pytest.raises(ValueError, match="microbatch"):
        _build(dist=DistConfig(microbatch=-1))


def test_batch_global_statistic_loss_rejects_microbatch():
    """GRPO-Guard's RatioNorm is a batch-global mean; chunked accumulation
    would silently recentre per chunk, so construction must refuse."""
    with pytest.raises(ValueError, match="batch-global"):
        _build("grpo_guard", dist=DistConfig(microbatch=2))
    _build("grpo_guard")                               # full-batch path fine


# ---------------------------------------------------------------- validation

def test_data_parallel_exceeding_devices_raises():
    too_many = jax.local_device_count() + 1
    with pytest.raises(ValueError, match="device"):
        distributed.data_mesh(DistConfig(data_parallel=too_many))


def test_single_device_resolves_to_no_mesh():
    assert distributed.data_mesh(DistConfig(data_parallel=1)) is None
    tr = _build(dist=DistConfig(data_parallel=1))
    assert tr.mesh is None


def test_group_size_validated_at_construction():
    cfg = configs.get_reduced("flux_dit")
    bad = FlowRLConfig(num_steps=3, group_size=0, latent_tokens=8,
                       latent_dim=8)
    with pytest.raises(ValueError, match="group_size"):
        registry.build("trainer", "flow_grpo", cfg, bad, TINY_OPT, key=KEY)


# ------------------------------------------------- multi-device (subprocess)

def _run_with_host_devices(code: str, n: int = 4) -> str:
    """Run ``code`` in a subprocess that fakes ``n`` CPU host devices (the
    flag must be set before jax initializes, hence the fresh process)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={n}")
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(REPO, "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=540, cwd=REPO)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    return proc.stdout


_EQUIV_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro import configs, registry
from repro.config import DistConfig, FlowRLConfig, OptimConfig, RewardSpec

assert jax.local_device_count() == 4, jax.devices()
FLOW = FlowRLConfig(num_steps=3, group_size=4, latent_tokens=8, latent_dim=8,
                    clip_range=0.2,
                    rewards=(RewardSpec("text_render", 1.0,
                             args={"latent_dim": 8, "latent_tokens": 8}),))
OPT = OptimConfig(lr=1e-3, total_steps=20, warmup_steps=2)
ARCH = configs.get_reduced("flux_dit")

def train(dist):
    key = jax.random.PRNGKey(0)
    tr = registry.build("trainer", "flow_grpo", ARCH, FLOW, OPT, key=key,
                        dtype=jnp.float32, dist=dist)
    cond = jax.random.normal(jax.random.PRNGKey(1), (4, 4, 512), jnp.float32)
    hist = [{k: float(v) for k, v in tr.step(cond, key, it=it).items()}
            for it in range(3)]
    return tr, hist

t1, h1 = train(DistConfig(data_parallel=1))
t4, h4 = train(DistConfig(data_parallel=4))
t4m, h4m = train(DistConfig(data_parallel=4, microbatch=2))

# the sharded trainer's state is really replicated across all 4 devices
leaf = jax.tree.leaves(t4.state.params)[0]
assert len(leaf.sharding.device_set) == 4, leaf.sharding
# and its rollouts are really batch-sharded
traj = t4.sample(t4.state.params, jax.random.normal(
    jax.random.PRNGKey(1), (4, 4, 512), jnp.float32), jax.random.PRNGKey(0))
assert len(traj.cond.sharding.device_set) == 4, traj.cond.sharding

for name, hx in (("dp4", h4), ("dp4+mb2", h4m)):
    for a, b in zip(h1, hx):
        for k in ("reward_mean", "loss", "grad_norm"):
            assert abs(a[k] - b[k]) <= 2e-4 + 1e-3 * abs(a[k]), \
                (name, k, a[k], b[k])
# AdamW turns reduction-order grad noise into ~lr-scale differences where
# vhat ~ 0, hence the absolute band of ~1e-4 on a tiny element fraction
for name, tx in (("dp4", t4), ("dp4+mb2", t4m)):
    for x, y in zip(jax.tree.leaves(t1.state.params),
                    jax.tree.leaves(tx.state.params)):
        np.testing.assert_allclose(np.asarray(y), np.asarray(x),
                                   rtol=1e-3, atol=2e-4, err_msg=name)
print("EQUIV-OK")
"""


def test_sharded_training_matches_single_device():
    """4-device data-parallel (and data-parallel + microbatch) training is
    numerically equivalent to single-device: same per-step metrics and the
    same final params within f32 reduction-order tolerance."""
    out = _run_with_host_devices(_EQUIV_SCRIPT)
    assert "EQUIV-OK" in out


_SHARD_MAP_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro import configs, registry
from repro.config import DistConfig, FlowRLConfig, OptimConfig, RewardSpec
from repro.core.rollout import group_repeat
from repro.distributed import data_mesh, make_rollout_sharded

assert jax.local_device_count() == 4
FLOW = FlowRLConfig(num_steps=3, group_size=4, latent_tokens=8, latent_dim=8,
                    rewards=(RewardSpec("text_render", 1.0,
                             args={"latent_dim": 8, "latent_tokens": 8}),))
OPT = OptimConfig(lr=1e-3, total_steps=20, warmup_steps=2)
tr = registry.build("trainer", "awm", configs.get_reduced("flux_dit"),
                    FLOW, OPT, key=jax.random.PRNGKey(0), dtype=jnp.float32)
mesh = data_mesh(DistConfig(data_parallel=4))
cond = group_repeat(jax.random.normal(jax.random.PRNGKey(1), (2, 4, 512),
                                      jnp.float32), 4)     # B = 8
run = make_rollout_sharded(tr.adapter, tr.scheduler, 3, mesh)  # build once
traj = run(tr.state.params, cond, jax.random.PRNGKey(2))
traj_b = run(tr.state.params, cond, jax.random.PRNGKey(3))     # ...reuse
assert not np.allclose(np.asarray(traj.x0), np.asarray(traj_b.x0))
assert traj.xs.shape == (4, 8, 8, 8), traj.xs.shape
assert np.isfinite(np.asarray(traj.xs)).all()
assert len(traj.xs.sharding.device_set) == 4
# per-shard key folds: different shards draw different noise
x0 = np.asarray(traj.x0)
assert not np.allclose(x0[:2], x0[2:4])
# indivisible batch is rejected clearly
try:
    run(tr.state.params, cond[:6], jax.random.PRNGKey(2))
except ValueError as e:
    assert "divisible" in str(e)
else:
    raise AssertionError("expected ValueError for B=6 on 4 devices")
print("SHARDMAP-OK")
"""


def test_shard_map_rollout_entry_point():
    """The communication-free shard_map rollout produces well-formed sharded
    trajectories with independent per-shard noise."""
    out = _run_with_host_devices(_SHARD_MAP_SCRIPT)
    assert "SHARDMAP-OK" in out

# ------------------------------------------------------- 2-D axis resolution

def test_resolve_axes_defaults_and_auto():
    n = jax.local_device_count()
    assert distributed.resolve_axes(DistConfig()) == (1, 1)
    # data_parallel=0 claims every device not claimed by model_parallel
    assert distributed.resolve_axes(DistConfig(data_parallel=0)) == (n, 1)
    # both auto resolves to all-data (the historical data_parallel=0)
    assert distributed.resolve_axes(
        DistConfig(data_parallel=0, model_parallel=0)) == (n, 1)
    # model_parallel=0 claims the devices data_parallel left over
    assert distributed.resolve_axes(
        DistConfig(data_parallel=1, model_parallel=0)) == (1, n)


def test_resolve_axes_validation():
    n = jax.local_device_count()
    with pytest.raises(ValueError, match="XLA_FLAGS"):
        distributed.resolve_axes(DistConfig(data_parallel=2 * n,
                                            model_parallel=n))
    with pytest.raises(ValueError, match="model_parallel"):
        distributed.resolve_axes(DistConfig(model_parallel=n + 1))
    with pytest.raises(ValueError, match=">= 0"):
        distributed.resolve_axes(DistConfig(data_parallel=-1))
    with pytest.raises(ValueError, match=">= 0"):
        distributed.resolve_axes(DistConfig(model_parallel=-2))


def test_train_mesh_degradation_tiers():
    """dp×mp=1 -> no mesh; mp=1 -> the historical 1-D ("data",) mesh."""
    assert distributed.train_mesh(
        DistConfig(data_parallel=1, model_parallel=1)) is None
    n = jax.local_device_count()
    if n > 1:
        mesh = distributed.train_mesh(DistConfig(data_parallel=n))
        assert mesh.axis_names == (distributed.DATA_AXIS,)
        assert distributed.mesh_dp(mesh) == n
        assert distributed.mesh_mp(mesh) == 1
    assert distributed.mesh_dp(None) == 1 and distributed.mesh_mp(None) == 1


def test_model_shard_dim_choices():
    from repro.models.params import model_shard_dim
    # mp=1 never shards
    assert model_shard_dim((8, 64), ("embed", "mlp"), 1) is None
    # priority: experts beats heads beats wide dims beats embed
    assert model_shard_dim((4, 16, 64), ("experts", "embed", "moe_f"), 2) == 0
    assert model_shard_dim((8, 16, 64), ("heads", "head_dim", "embed"), 2) == 0
    assert model_shard_dim((64, 256), ("embed", "mlp"), 2) == 1
    # norm / head_dim / conv scales stay replicated
    assert model_shard_dim((64,), ("norm",), 2) is None
    assert model_shard_dim((16,), ("head_dim",), 2) is None
    # indivisible or too-small dims are skipped, falling through by priority
    assert model_shard_dim((3, 64), ("experts", "embed"), 2) == 1
    assert model_shard_dim((1, 1), ("experts", "embed"), 2) is None


def test_partition_plan_layouts_and_bytes():
    """PartitionPlan on an explicitly built 2-D mesh: params shard along
    "model", AdamW moments inherit their param's sharding leaf-for-leaf,
    scalars stay replicated, and the per-device byte report shrinks."""
    if jax.local_device_count() < 4:
        pytest.skip("needs 4 (faked) devices — runs in make test-dist")
    from jax.sharding import Mesh, PartitionSpec
    mesh = Mesh(np.asarray(jax.local_devices()[:4]).reshape(2, 2),
                (distributed.DATA_AXIS, distributed.MODEL_AXIS))
    tr = _build()                                  # single-device trainer
    plan = distributed.partition_plan(mesh, tr.adapter.spec())
    psh = plan.param_shardings()
    specs = [s.spec for s in jax.tree.leaves(
        psh, is_leaf=lambda x: hasattr(x, "spec"))]
    assert any(distributed.MODEL_AXIS in [e for ent in s if ent is not None
               for e in (ent if isinstance(ent, tuple) else (ent,))]
               for s in specs), "no leaf sharded over the model axis"
    ssh = plan.state_shardings(tr.state)
    # mu/nu mirror params: same sharding tree; step counter replicated
    assert jax.tree.structure(ssh.opt.mu, is_leaf=lambda x: hasattr(
        x, "spec")) == jax.tree.structure(psh, is_leaf=lambda x: hasattr(
            x, "spec"))
    for a, b in zip(jax.tree.leaves(ssh.params,
                                    is_leaf=lambda x: hasattr(x, "spec")),
                    jax.tree.leaves(ssh.opt.mu,
                                    is_leaf=lambda x: hasattr(x, "spec"))):
        assert a.spec == b.spec
    assert ssh.opt.step.spec == PartitionSpec()
    rep = plan.bytes_report(tr.state)
    assert rep["sharded_leaves"] > 0
    assert rep["per_device_bytes"] < rep["total_bytes"]
    # the report is consistent with actually placing the state
    placed = jax.device_put(tr.state, ssh)
    leaf = jax.tree.leaves(placed.params)[0]
    assert len(leaf.sharding.device_set) == 4


_TWO_AXIS_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro import configs, registry, distributed
from repro.config import DistConfig, FlowRLConfig, OptimConfig, RewardSpec

assert jax.local_device_count() == 4, jax.devices()
FLOW = FlowRLConfig(num_steps=3, group_size=4, latent_tokens=8, latent_dim=8,
                    clip_range=0.2,
                    rewards=(RewardSpec("text_render", 1.0,
                             args={"latent_dim": 8, "latent_tokens": 8}),))
OPT = OptimConfig(lr=1e-3, total_steps=20, warmup_steps=2)
ARCH = configs.get_reduced("flux_dit")
TNAME = "__TNAME__"

def train(dist):
    key = jax.random.PRNGKey(0)
    tr = registry.build("trainer", TNAME, ARCH, FLOW, OPT, key=key,
                        dtype=jnp.float32, dist=dist)
    cond = jax.random.normal(jax.random.PRNGKey(1), (4, 4, 512), jnp.float32)
    hist = [{k: float(v) for k, v in tr.step(cond, key, it=it).items()}
            for it in range(3)]
    return tr, hist

# 2-D FIRST: building the mesh enables partitionable threefry (sharding-
# invariant RNG), so the single-device reference draws the same stream
t22, h22 = train(DistConfig(data_parallel=2, model_parallel=2))
t1, h1 = train(DistConfig())

assert t22.mesh.axis_names == ("data", "model"), t22.mesh
assert t22.plan is not None and t22.plan.model_parallel == 2
rep = t22.plan.bytes_report(t22.state)
assert rep["sharded_leaves"] > 0, rep
assert rep["per_device_bytes"] < rep["total_bytes"], rep
# at least one live param leaf is genuinely model-sharded across 4 devices
shards = [leaf.sharding for leaf in jax.tree.leaves(t22.state.params)]
assert any(len(s.device_set) == 4 and not s.is_fully_replicated
           for s in shards), shards

for a, b in zip(h1, h22):
    for k in ("reward_mean", "loss", "grad_norm"):
        assert abs(a[k] - b[k]) <= 2e-4 + 1e-3 * abs(a[k]), (k, a[k], b[k])
# documented f32 band: model-axis collectives reorder reductions, and AdamW
# turns that noise into ~lr-scale sign flips where vhat ~ 0.  Every element
# is capped at a few x lr (a flipped element moves <= 2*lr per step), and
# at most a 0.01% tail may sit outside the tight band the rest must meet.
n_tot = n_out = 0
for x, y in zip(jax.tree.leaves(t1.state.params),
                jax.tree.leaves(t22.state.params)):
    x, y = np.asarray(x), np.asarray(y)
    np.testing.assert_allclose(y, x, rtol=0, atol=5e-3)
    n_out += int((np.abs(y - x) > (2e-4 + 1e-3 * np.abs(x))).sum())
    n_tot += x.size
assert n_out <= max(1, n_tot // 10_000), (n_out, n_tot)
print("TWO-AXIS-OK")
"""


@pytest.mark.parametrize("tname", ["flow_grpo", "grpo_guard", "nft", "awm"])
def test_two_axis_training_matches_single_device(tname):
    """dp=2×mp=2 on 4 faked devices trains equivalently to single-device
    (documented f32 tolerance) for every trainer family, with params
    genuinely sharded over the model axis."""
    out = _run_with_host_devices(
        _TWO_AXIS_SCRIPT.replace("__TNAME__", tname))
    assert "TWO-AXIS-OK" in out


_PORTABLE_SCRIPT = r"""
import os, tempfile
import jax, jax.numpy as jnp
import numpy as np
from repro import checkpoint, configs, registry
from repro.config import DistConfig, FlowRLConfig, OptimConfig, RewardSpec

assert jax.local_device_count() == 4, jax.devices()
FLOW = FlowRLConfig(num_steps=3, group_size=4, latent_tokens=8, latent_dim=8,
                    clip_range=0.2,
                    rewards=(RewardSpec("text_render", 1.0,
                             args={"latent_dim": 8, "latent_tokens": 8}),))
OPT = OptimConfig(lr=1e-3, total_steps=20, warmup_steps=2)
ARCH = configs.get_reduced("flux_dit")
key = jax.random.PRNGKey(0)
cond = jax.random.normal(jax.random.PRNGKey(1), (4, 4, 512), jnp.float32)

# train under dp=4, checkpoint (device_get gathers -> canonical layout)
t4 = registry.build("trainer", "flow_grpo", ARCH, FLOW, OPT, key=key,
                    dtype=jnp.float32, dist=DistConfig(data_parallel=4))
for it in range(2):
    t4.step(cond, key, it=it)
ckpt_dir = tempfile.mkdtemp()
checkpoint.save_checkpoint(ckpt_dir, 2, t4.state)
saved = jax.device_get(t4.state)

# resume under dp=2×mp=2: restore canonical, re-place per the new plan
t22 = registry.build("trainer", "flow_grpo", ARCH, FLOW, OPT, key=key,
                     dtype=jnp.float32,
                     dist=DistConfig(data_parallel=2, model_parallel=2))
step, state = checkpoint.restore_latest(ckpt_dir, t22.state)
assert step == 2
t22.state = t22.place_state(state)

# params (and moments) are bitwise what dp=4 wrote...
for a, b in zip(jax.tree.leaves(saved), jax.tree.leaves(
        jax.device_get(t22.state))):
    assert np.array_equal(np.asarray(a), np.asarray(b))
# ...yet live on the 2-D layout, model-sharded
shards = [leaf.sharding for leaf in jax.tree.leaves(t22.state.params)]
assert any(len(s.device_set) == 4 and not s.is_fully_replicated
           for s in shards), shards
# and training continues from it
m = t22.step(cond, key, it=2)
assert np.isfinite(float(m["loss"]))
print("PORTABLE-OK")
"""


def test_checkpoint_portable_across_mesh_layouts():
    """A checkpoint written under dp=4 restores bitwise under dp=2×mp=2:
    layouts are a runtime choice, the on-disk layout is canonical."""
    out = _run_with_host_devices(_PORTABLE_SCRIPT)
    assert "PORTABLE-OK" in out
