"""Preprocessing-based memory optimization tests (paper §2.2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.preprocess import (ConditionProvider, FrozenTextEncoder,
                                   PreprocessCache, preprocess_dataset)
from repro.data import synthetic_prompts

ENC_KW = dict(cond_dim=32, cond_len=4, vocab=256, hidden=64)


def test_cache_roundtrip(tmp_path):
    cache = PreprocessCache(str(tmp_path))
    arr = {"cond": np.random.randn(4, 32).astype(np.float32),
           "pooled": np.random.randn(32).astype(np.float32)}
    cache.put("a fox in watercolor", arr)
    assert cache.has("a fox in watercolor")
    back = cache.get("a fox in watercolor")
    np.testing.assert_array_equal(back["cond"], arr["cond"])


def test_cached_equals_fresh(tmp_path):
    """Phase-1 cached embeddings are bit-identical to live encoding — the
    optimization never changes training inputs."""
    prompts = synthetic_prompts(8)
    cache = PreprocessCache(str(tmp_path))
    n = preprocess_dataset(prompts, cache, encoder=FrozenTextEncoder(**ENC_KW))
    assert n == 8
    cached = ConditionProvider(preprocessing=True, cache=cache)
    live = ConditionProvider(preprocessing=False, encoder_kw=ENC_KW)
    a = cached.get(prompts[:4])
    b = live.get(prompts[:4])
    np.testing.assert_allclose(np.asarray(a["cond"]), np.asarray(b["cond"]),
                               rtol=1e-6)


def test_offload_guarantee(tmp_path):
    """With preprocessing on, the frozen encoder is NEVER instantiated."""
    prompts = synthetic_prompts(4)
    cache = PreprocessCache(str(tmp_path))
    preprocess_dataset(prompts, cache, encoder=FrozenTextEncoder(**ENC_KW))
    provider = ConditionProvider(preprocessing=True, cache=cache)
    provider.get(prompts)
    provider.get(prompts)
    assert not provider.encoder_resident
    assert provider.resident_param_bytes == 0
    baseline = ConditionProvider(preprocessing=False, encoder_kw=ENC_KW)
    baseline.get(prompts)
    assert baseline.encoder_resident
    assert baseline.resident_param_bytes > 0


def test_preprocess_is_resumable(tmp_path):
    prompts = synthetic_prompts(6)
    cache = PreprocessCache(str(tmp_path))
    enc = FrozenTextEncoder(**ENC_KW)
    assert preprocess_dataset(prompts[:3], cache, encoder=enc) == 3
    assert preprocess_dataset(prompts, cache, encoder=enc) == 3  # only new


def test_cache_miss_is_clear_keyerror(tmp_path):
    """A miss names the missing prompt instead of leaking a bare
    FileNotFoundError from the cache internals."""
    cache = PreprocessCache(str(tmp_path))
    provider = ConditionProvider(preprocessing=True, cache=cache)
    with pytest.raises(KeyError, match="unseen prompt"):
        provider.get(["unseen prompt"])
    with pytest.raises(KeyError, match="encode_on_miss"):
        provider.get(["unseen prompt"])
    assert not provider.encoder_resident   # failure didn't load the tower


def test_cache_miss_encode_on_miss(tmp_path):
    prompts = synthetic_prompts(4)
    cache = PreprocessCache(str(tmp_path))
    preprocess_dataset(prompts[:2], cache, encoder=FrozenTextEncoder(**ENC_KW))
    provider = ConditionProvider(preprocessing=True, cache=cache,
                                 encoder_kw=ENC_KW, encode_on_miss=True)
    out = provider.get(prompts)            # 2 hits + 2 lazily encoded
    assert out["cond"].shape[0] == 4
    assert provider.encoder_resident       # opt-in forfeits the offload
    assert all(cache.has(p) for p in prompts)   # misses were backfilled
    # backfilled entries match what a fresh full preprocess would produce
    live = ConditionProvider(preprocessing=False, encoder_kw=ENC_KW)
    np.testing.assert_allclose(np.asarray(out["cond"]),
                               np.asarray(live.get(prompts)["cond"]),
                               rtol=1e-6)
