"""Model-substrate correctness: chunked attention vs exact, SSD chunked vs
sequential, MLA absorbed-decode vs expanded, prefill+decode vs full forward,
MoE dispatch vs dense-oracle."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import MoEConfig
from repro.kernels import ref
from repro.models import moe, ssm, tasks
from repro.models.backbone import Backbone
from repro.models.layers import attention_chunked, chunked_ce_loss

KEY = jax.random.PRNGKey(7)


def test_attention_chunked_equals_unchunked():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 192, 4, 32))
    k = jax.random.normal(ks[1], (2, 192, 2, 32))
    v = jax.random.normal(ks[2], (2, 192, 2, 32))
    full = attention_chunked(q, k, v, causal=True, chunk_q=192)
    chunked = attention_chunked(q, k, v, causal=True, chunk_q=64)
    ragged = attention_chunked(q, k, v, causal=True, chunk_q=80)  # remainder
    np.testing.assert_allclose(full, chunked, atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(full, ragged, atol=2e-5, rtol=1e-4)


def test_sliding_window_matches_masked_full():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    got = attention_chunked(q, k, v, causal=True, window=32, chunk_q=48)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=1e-4)


def test_ssd_chunked_equals_sequential():
    cfg = configs.get_reduced("mamba2-370m")
    ks = jax.random.split(KEY, 5)
    B, L = 2, 96
    m = ssm.dims(cfg)
    x = jax.random.normal(ks[0], (B, L, m["H"], m["P"]))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, m["H"])))
    a = -jnp.exp(jax.random.normal(ks[2], (m["H"],)) * 0.3)
    bm = jax.random.normal(ks[3], (B, L, m["N"])) * 0.5
    cm = jax.random.normal(ks[4], (B, L, m["N"])) * 0.5
    y, hT = ssm.ssd_chunked(x, dt, a, bm, cm, chunk=32)
    yr, hr = ref.ssd_scan_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(y, yr, atol=5e-3, rtol=0.05)
    np.testing.assert_allclose(hT, hr, atol=5e-3, rtol=0.05)


def test_ssd_state_chaining():
    """Scanning [first half] then [second half with carried state] equals the
    full scan — the distributed sequence-parallel invariant."""
    cfg = configs.get_reduced("mamba2-370m")
    ks = jax.random.split(KEY, 5)
    B, L = 1, 64
    m = ssm.dims(cfg)
    x = jax.random.normal(ks[0], (B, L, m["H"], m["P"]))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, m["H"])))
    a = -jnp.exp(jax.random.normal(ks[2], (m["H"],)) * 0.3)
    bm = jax.random.normal(ks[3], (B, L, m["N"])) * 0.5
    cm = jax.random.normal(ks[4], (B, L, m["N"])) * 0.5
    y_full, h_full = ssm.ssd_chunked(x, dt, a, bm, cm, chunk=32)
    h = L // 2
    y1, h1 = ssm.ssd_chunked(x[:, :h], dt[:, :h], a, bm[:, :h], cm[:, :h],
                             chunk=32)
    y2, h2 = ssm.ssd_chunked(x[:, h:], dt[:, h:], a, bm[:, h:], cm[:, h:],
                             chunk=32, init_state=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=5e-3, rtol=0.05)
    np.testing.assert_allclose(h2, h_full, atol=5e-3, rtol=0.05)


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen3-32b", "mamba2-370m",
                                  "zamba2-2.7b", "deepseek-v2-236b",
                                  "internvl2-1b", "grok-1-314b"])
def test_decode_consistent_with_forward(arch, rng_key):
    """prefill(x[:S]) + decode(x[S]) == forward(x[:S+1]) last logits."""
    cfg = configs.get_reduced(arch)
    p = tasks.init_params(cfg, rng_key, jnp.float32)
    S = 24
    batch = tasks.synthetic_batch(cfg, 2, S + 1, rng_key)
    toks = batch["tokens"]
    pre_batch = {"tokens": toks[:, :S]}
    if "prefix_embed" in batch:
        pre_batch["prefix_embed"] = batch["prefix_embed"]
    _, caches = tasks.make_prefill_step(cfg)(p, pre_batch)
    # absolute position of the new token includes any frontend prefix
    pos = S + cfg.frontend.n_tokens
    logits_dec, _ = tasks.make_decode_step(cfg)(
        p, caches, toks[:, S:S + 1], jnp.int32(pos))

    model = Backbone(cfg)
    x = model.embed_inputs(p, toks, batch.get("prefix_embed"))
    hidden, _, _ = model.forward_embeds(p, x, causal=True)
    logits_full = model.logits(p, hidden[:, -1])
    np.testing.assert_allclose(logits_dec, logits_full, atol=2e-2, rtol=2e-2)


def test_moe_matches_dense_oracle_when_capacity_ample():
    """With capacity >= tokens, scatter-dispatch output == computing every
    expert densely and mixing by gates."""
    cfg = configs.get_reduced("grok-1-314b")
    p_spec = moe.spec(cfg)
    from repro.models import params as params_lib
    p = params_lib.init(p_spec, KEY, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    y, aux = moe.apply(p, cfg, x)

    m = cfg.moe
    logits = jnp.einsum("btd,de->bte", x, p["router"])
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    g = jnp.einsum("btd,edf->btef", x, p["w_gate"])
    u = jnp.einsum("btd,edf->btef", x, p["w_up"])
    h = jax.nn.silu(g) * u
    all_y = jnp.einsum("btef,efd->bted", h, p["w_down"])
    sel = jnp.take_along_axis(all_y, idx[..., None], axis=2)
    want = (sel * gates[..., None]).sum(2)
    np.testing.assert_allclose(y, want, atol=1e-4, rtol=1e-3)
    assert jnp.isfinite(aux["moe_lb_loss"])


def test_moe_capacity_drops_tokens():
    cfg = dataclasses.replace(
        configs.get_reduced("grok-1-314b"),
        moe=MoEConfig(n_experts=4, top_k=4, expert_d_ff=64))
    from repro.models import params as params_lib
    p = params_lib.init(moe.spec(cfg), KEY, jnp.float32)
    # all tokens pick every expert (top_k = E) -> capacity must bind
    x = jnp.ones((1, 64, cfg.d_model)) * 0.1
    C = moe.capacity(64, cfg)
    assert C < 64 * 4
    y, _ = moe.apply(p, cfg, x)
    assert jnp.isfinite(y).all()


def test_chunked_ce_matches_direct():
    B, S, d, V = 2, 48, 16, 64
    ks = jax.random.split(KEY, 3)
    h = jax.random.normal(ks[0], (B, S, d))
    w = jax.random.normal(ks[1], (d, V)) * 0.1
    y = jax.random.randint(ks[2], (B, S), 0, V)
    got = chunked_ce_loss(h, w, y, chunk=16)
    logits = h @ w
    want = (jax.nn.logsumexp(logits, -1)
            - jnp.take_along_axis(logits, y[..., None], -1)[..., 0]).mean()
    np.testing.assert_allclose(got, want, rtol=1e-5)
    ragged = chunked_ce_loss(h, w, y, chunk=20)
    np.testing.assert_allclose(ragged, want, rtol=1e-5)


def test_mla_cache_is_rank_compressed():
    cfg = configs.get_reduced("deepseek-v2-236b")
    model = Backbone(cfg)
    spec = model.cache_specs(batch=2, cache_len=64)

    shapes = []

    def walk(node):
        if (isinstance(node, tuple) and len(node) == 2
                and isinstance(node[0], tuple)
                and all(isinstance(d, int) for d in node[0])):
            shapes.append(node[0])
            return
        if isinstance(node, (tuple, list)):
            for c in node:
                walk(c)

    walk(spec)
    # MLA caches store (..., T, rank) latents, never (..., T, H, hd)
    assert shapes, spec
    assert any(s[-1] == cfg.mla.kv_lora_rank for s in shapes)
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    assert not any(s[-2:] == (H, hd) for s in shapes)
