"""Pipelined-vs-sequential TrainLoop equivalence suite.

The exactness contract of ``loop.pipeline`` (see repro.api.loop):

* ``pipeline=1`` is BIT-IDENTICAL to the sequential dispatch→drain loop —
  same params after N AdamW steps, same metric history;
* ``pipeline=K>1`` changes only WHEN metrics are observed (rows arrive up
  to K-1 steps after dispatch), never WHAT is computed — params and the
  metric values stay bitwise equal across K;
* a checkpoint taken mid-pipeline sees exactly-post-step state (the
  ``wants_sync`` drain barrier), so crash/resume stays bit-identical;
* the dataset ``skip(n)`` fast path and the replay-skip fallback position
  a resumed stream identically.

The data×pipeline composition test needs 4 faked devices and is skipped
elsewhere; `make test-pipeline` re-runs this file with XLA_FLAGS set.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, registry
from repro.api import Experiment, loop as loop_lib
from repro.config import (DataConfig, DistConfig, FlowRLConfig, LoopConfig,
                          OptimConfig, PerfConfig, RewardSpec, RunConfig)
from repro.core.preprocess import ConditionProvider
from repro.data.prompts import PromptDataset, synthetic_prompts

TINY_ENCODER = dict(cond_dim=32, cond_len=4, vocab=256, hidden=64)
KEY = jax.random.PRNGKey(7)

TINY_FLOW = FlowRLConfig(
    num_steps=2, group_size=2, latent_tokens=4, latent_dim=4,
    rewards=(RewardSpec("text_render", 1.0,
                        args={"latent_dim": 4, "latent_tokens": 4,
                              "cond_dim": 32}),))
TINY_OPT = OptimConfig(lr=1e-3, total_steps=64, warmup_steps=2)

needs4 = pytest.mark.skipif(
    jax.local_device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4")


def _trainer(perf=None, dist=None):
    return registry.build("trainer", "flow_grpo",
                          configs.get_reduced("flux_dit"), TINY_FLOW,
                          TINY_OPT, key=jax.random.PRNGKey(0), cond_dim=32,
                          perf=perf, dist=dist)


def _provider():
    return ConditionProvider(preprocessing=False, encoder_kw=TINY_ENCODER)


def _dataset():
    return PromptDataset(synthetic_prompts(16), batch_size=4, seed=0)


def _loop(trainer, steps=6, pipeline=1, start_step=0, callbacks=(),
          dataset=None):
    return loop_lib.TrainLoop(trainer, _provider(),
                              dataset if dataset is not None else _dataset(),
                              steps=steps, key=KEY, start_step=start_step,
                              callbacks=callbacks, pipeline=pipeline)


def _bits(tree):
    """Bitwise-comparable leaves (bf16 viewed as u16)."""
    out = []
    for x in jax.tree.leaves(tree):
        arr = np.asarray(jax.device_get(x))
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        out.append(arr)
    return out


def _rows(history):
    """History minus the wall-clock keys (the only K-dependent fields)."""
    return [{k: v for k, v in r.items() if k not in ("dt", "steps_per_s")}
            for r in history]


def _assert_same_params(tr_a, tr_b):
    la, lb = _bits(tr_a.state.params), _bits(tr_b.state.params)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


# ------------------------------------------------- pipeline=1 == sequential

def _sequential_reference(trainer, provider, dataset, steps, key):
    """The pre-pipeline loop, hand-rolled: dispatch one step, immediately
    device_get its metrics, repeat."""
    stream = dataset.infinite(0)
    history = []
    for it in range(steps):
        prompts = next(stream)
        cond = provider.get(prompts)["cond"]
        metrics = trainer.step(cond, key, it=it)
        m = jax.tree.map(float, jax.device_get(metrics))
        row = {"step": it, "reward": m["reward_mean"], "loss": m["loss"],
               "grad_norm": m["grad_norm"],
               "encode_resident": provider.encoder_resident}
        row.update({k: v for k, v in m.items() if k.startswith("reward/")})
        history.append(row)
    return history


def test_pipeline1_bitwise_equals_sequential_reference():
    ref_tr = _trainer()
    ref_hist = _sequential_reference(ref_tr, _provider(), _dataset(), 6, KEY)
    tr = _trainer()
    hist = _loop(tr, steps=6, pipeline=1).run()
    _assert_same_params(ref_tr, tr)
    assert _rows(hist) == ref_hist


# ------------------------------------------- pipeline=K: lagged, same math

def test_pipeline4_same_math_lagged_observation():
    tr1 = _trainer()
    h1 = _loop(tr1, steps=6, pipeline=1).run()

    tr4 = _trainer()
    dispatched = []
    orig_step = tr4.step

    def counting_step(cond, key, *, it):
        dispatched.append(it)
        return orig_step(cond, key, it=it)

    tr4.step = counting_step
    lags = []

    class Lag(loop_lib.Callback):
        def on_step(self, loop, step, metrics):
            lags.append(max(dispatched) - step)

    h4 = _loop(tr4, steps=6, pipeline=4, callbacks=[Lag()]).run()

    _assert_same_params(tr1, tr4)
    assert _rows(h4) == _rows(h1)            # same values, same order
    # ...but observed late: when step 0's row lands, steps 1..3 were
    # already dispatched (depth-K lag, bounded by K-1)
    assert max(lags) >= 1
    assert all(0 <= lag <= 3 for lag in lags)


def test_pipeline4_undonated_bitwise_equals_donated_sequential():
    """The benchmark's run-ahead regime: on the CPU PJRT client donated
    executions run synchronously, so the pipelined configs run with
    ``dist.donate_state=false``.  Donation is a pure buffer policy —
    un-donated K=4 must stay bitwise equal to the donated K=1 loop."""
    tr1 = _trainer()
    h1 = _loop(tr1, steps=6, pipeline=1).run()
    tr4 = _trainer(dist=DistConfig(donate_state=False))
    h4 = _loop(tr4, steps=6, pipeline=4).run()
    _assert_same_params(tr1, tr4)
    assert _rows(h4) == _rows(h1)


def test_pipeline_depth_validated():
    with pytest.raises(ValueError, match="pipeline"):
        _loop(_trainer(), pipeline=0)


# ------------------------------------- checkpoint/resume mid-pipeline

def _tiny_cfg(tmp_path, steps, save_every=0, **loop_kw):
    return RunConfig(
        arch="flux_dit", reduced=True,
        flow=FlowRLConfig(num_steps=2, group_size=2, latent_tokens=4,
                          latent_dim=4, rewards=(),
                          cache_dir=str(tmp_path / "cache")),
        optim=OptimConfig(lr=1e-3, total_steps=8, warmup_steps=1),
        data=DataConfig(n_prompts=8, batch_prompts=2, encoder=TINY_ENCODER),
        loop=LoopConfig(steps=steps, save_every=save_every, log_every=0,
                        ckpt_dir=str(tmp_path / "ckpt"), **loop_kw))


def test_checkpoint_resume_mid_pipeline_bit_identical(tmp_path):
    """A K=4 run interrupted at its step-2 checkpoint and resumed equals an
    uninterrupted K=1 run — the wants_sync barrier makes the checkpoint see
    exactly-post-step state even with steps in flight."""
    straight = Experiment.from_config(
        _tiny_cfg(tmp_path / "a", steps=4, save_every=2)).train()
    Experiment.from_config(
        _tiny_cfg(tmp_path / "b", steps=2, save_every=2, pipeline=4)).train()
    resumed = Experiment.from_config(
        _tiny_cfg(tmp_path / "b", steps=4, save_every=2, pipeline=4)).train()
    assert resumed["start_step"] == 2
    la, lb = _bits(straight["state"]), _bits(resumed["state"])
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


# --------------------------------------- resume stream positioning (skip)

def test_dataset_skip_fast_path_equivalence():
    """``infinite(skip=n)`` equals dropping n batches from ``infinite(0)``,
    including across an epoch boundary (4 batches/epoch here)."""
    per = _dataset().batches_per_epoch
    assert per == 4
    for skip in (0, 1, per, per + 2, 3 * per + 1):
        slow = _dataset().infinite()
        for _ in range(skip):
            next(slow)
        fast = _dataset().infinite(skip)
        for _ in range(2 * per):
            assert next(fast) == next(slow)


class _NoSkipDataset:
    """Dataset without the skip parameter — exercises TrainLoop's
    replay-skip fallback."""

    def __init__(self):
        self._ds = _dataset()

    def infinite(self):
        return self._ds.infinite()


def test_resume_equivalence_skip_and_fallback():
    """Resuming at start_step positions the stream identically through the
    O(1) skip fast path and the replay-skip fallback: both finish with the
    params of an uninterrupted run."""
    tr_full = _trainer()
    _loop(tr_full, steps=6).run()

    for dataset in (_dataset(), _NoSkipDataset()):
        tr = _trainer()
        _loop(tr, steps=3).run()
        lp = _loop(tr, steps=6, start_step=3, dataset=dataset)
        lp.run()
        _assert_same_params(tr_full, tr)


# -------------------------------------- composition: fused × dp=4 × K

@needs4
def test_pipeline_composes_with_fused_and_data_parallel():
    perf = PerfConfig(fuse_step=True, offload_rewards=True)
    dist = DistConfig(data_parallel=4)
    tr1 = _trainer(perf=perf, dist=dist)
    h1 = _loop(tr1, steps=4, pipeline=1).run()
    tr4 = _trainer(perf=perf, dist=dist)
    h4 = _loop(tr4, steps=4, pipeline=4).run()
    _assert_same_params(tr1, tr4)
    assert _rows(h4) == _rows(h1)
    assert tr4.offloads_rewards
