"""Substrate tests: optimizer, schedules, checkpointing, data, config IO,
registry errors."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, optim, registry
from repro.config import (FlowRLConfig, OptimConfig, RunConfig, from_dict,
                          to_dict)
from repro.data import PromptDataset, TokenStream, synthetic_prompts

KEY = jax.random.PRNGKey(9)


def test_adamw_matches_manual():
    cfg = OptimConfig(lr=0.1, betas=(0.9, 0.999), eps=1e-8, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    st = optim.adamw_init(p)
    p2, st2 = optim.adamw_update(p, g, st, cfg, jnp.float32(0.1))
    m = 0.1 * 0.5
    v = 0.001 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = 1.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(float(p2["w"][0]), want, rtol=1e-5)
    assert int(st2.step) == 1


def test_weight_decay_shrinks():
    cfg = OptimConfig(lr=0.1, weight_decay=0.1)
    p = {"w": jnp.ones((4,))}
    g = {"w": jnp.zeros((4,))}
    st = optim.adamw_init(p)
    p2, _ = optim.adamw_update(p, g, st, cfg, jnp.float32(0.1))
    assert float(p2["w"][0]) < 1.0


def test_clip_by_global_norm():
    g = {"a": jnp.ones((3,)) * 3.0, "b": jnp.ones((4,)) * 4.0}
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(gn), np.sqrt(9 * 3 + 16 * 4), rtol=1e-5)
    np.testing.assert_allclose(float(optim.global_norm(clipped)), 1.0,
                               rtol=1e-4)


def test_schedule_warmup_cosine():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lr = optim.make_schedule(cfg)
    assert float(lr(jnp.int32(0))) == pytest.approx(0.1)   # never zero
    assert float(lr(jnp.int32(9))) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(jnp.int32(99))) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(jnp.int32(50))) < float(lr(jnp.int32(20)))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.float32),
                       "step": jnp.int32(7)}}
    checkpoint.save_checkpoint(str(tmp_path), 3, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 3
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    back = checkpoint.load_checkpoint(str(tmp_path), 3, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_token_stream_learnable():
    ts = TokenStream(64, batch=4, seq=32, seed=0)
    b = next(ts.batches())
    assert b["tokens"].shape == (4, 32)
    # labels are next tokens
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


def test_prompt_dataset_sharding():
    prompts = synthetic_prompts(20)
    d0 = PromptDataset(prompts, 2, host_id=0, n_hosts=2)
    d1 = PromptDataset(prompts, 2, host_id=1, n_hosts=2)
    assert len(d0) + len(d1) == 20
    assert set(d0.prompts).isdisjoint(d1.prompts)


def test_config_dict_roundtrip():
    cfg = RunConfig()
    d = to_dict(cfg)
    back = from_dict(RunConfig, d)
    assert back == cfg


def test_registry_error_lists_available():
    import repro.core  # noqa: F401  (registers trainers)
    with pytest.raises(registry.RegistryError) as e:
        registry.lookup("trainer", "nope")
    assert "flow_grpo" in str(e.value)


def test_registry_rejects_duplicates():
    @registry.register("aggregator", "dup_test_agg")
    def f(*a):
        return None
    with pytest.raises(registry.RegistryError):
        @registry.register("aggregator", "dup_test_agg")
        def g(*a):
            return None
