"""Hypothesis property tests over the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import registry
from repro.core.rewards import group_normalize
from repro.core.schedulers import build as build_sched
from repro.kernels import ref

SET = dict(max_examples=25, deadline=None)


@given(st.integers(1, 6), st.integers(2, 8), st.floats(0.1, 10.0),
       st.floats(-5.0, 5.0), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_group_normalize_invariants(groups, gsize, scale, shift, seed):
    """Group-normalized advantages: zero group mean; invariant to per-group
    affine reward transforms (the GRPO scale-robustness property)."""
    r = jax.random.normal(jax.random.PRNGKey(seed), (groups * gsize,))
    z1 = group_normalize(r, gsize)
    z2 = group_normalize(r * scale + shift, gsize)
    np.testing.assert_allclose(np.asarray(z1.reshape(groups, gsize).mean(1)),
                               0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-3)


@given(st.floats(0.01, 0.5), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_ratio_clip_bounds(clip, seed):
    """Clipped objective is bounded by |adv|·(1+clip) wherever the advantage
    is positive (the PPO pessimism property)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    lpn = jax.random.normal(k1, (64,))
    lpo = jax.random.normal(k2, (64,))
    adv = jnp.abs(jax.random.normal(k3, (64,)))
    loss, _ = ref.grpo_loss_ref(lpn, lpo, adv, clip=clip)
    assert bool(jnp.all(-loss <= adv * (1.0 + clip) + 1e-5))


@given(st.sampled_from(["flow_sde", "dance_sde", "cps"]),
       st.floats(0.1, 0.9), st.integers(2, 16), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_scheduler_logprob_consistency(name, eta, steps, seed):
    s = build_sched(name, eta)
    ts = s.timesteps(steps)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (2, 4))
    v = jax.random.normal(k2, (2, 4)) * 0.5
    i = seed % steps
    x_next, lp = s.step(v, x, ts[i], ts[i + 1], k3)
    lp2 = s.logprob(v, x, ts[i], ts[i + 1], x_next)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp2),
                               rtol=1e-4, atol=1e-3)
    assert bool(jnp.all(jnp.isfinite(x_next)))


@given(st.integers(1, 3), st.integers(1, 4), st.integers(8, 32),
       st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ssd_scan_chaining_property(B, H, L2, seed):
    """Chunked SSD over [a; b] == scan(a) then scan(b, init=state(a)) — the
    invariant sequence-parallel sharding relies on."""
    L = 2 * L2
    P, N = 8, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    bm = jax.random.normal(ks[3], (B, L, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, L, N)) * 0.3
    y_full, h_full = ref.ssd_scan_ref(x, dt, a, bm, cm)
    y1, h1 = ref.ssd_scan_ref(x[:, :L2], dt[:, :L2], a, bm[:, :L2],
                              cm[:, :L2])
    y2, h2 = ref.ssd_scan_ref(x[:, L2:], dt[:, L2:], a, bm[:, L2:],
                              cm[:, L2:], init_state=h1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=1e-3, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, L2:]),
                               atol=1e-3, rtol=1e-2)


@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
@settings(max_examples=10, deadline=None)
def test_rope_relative_position_property(seed, shift):
    """RoPE attention scores depend only on relative positions: shifting all
    positions by a constant leaves q·k unchanged."""
    from repro.models.layers import apply_rope
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(k1, (1, 8, 2, 32))
    k = jax.random.normal(k2, (1, 8, 2, 32))
    pos = jnp.arange(8)
    s0 = jnp.einsum("bshd,bthd->bsth", apply_rope(q, pos, 1e4),
                    apply_rope(k, pos, 1e4))
    s1 = jnp.einsum("bshd,bthd->bsth", apply_rope(q, pos + shift, 1e4),
                    apply_rope(k, pos + shift, 1e4))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-3)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_registry_idempotent_lookup(seed):
    for kind in ("trainer", "scheduler", "reward", "aggregator"):
        for name in registry.names(kind):
            assert registry.lookup(kind, name) is registry.lookup(kind, name)


# --------------------------------------------------- rollout-level invariants

class _LinearAdapter:
    """Closed-form velocity field (v = w·x + t·c̄) — exercises the rollout
    integrators without a backbone, keeping hypothesis sweeps fast."""

    class flow_cfg:
        latent_tokens = 4
        latent_dim = 3

    def init_latent(self, key, batch):
        return jax.random.normal(key, (batch, 4, 3), jnp.float32)

    def velocity(self, params, x, t, cond):
        return params["w"] * x + t[:, None, None] * cond.mean(
            axis=(1, 2), keepdims=True)


_LIN_PARAMS = {"w": jnp.float32(-0.3)}


@given(st.sampled_from(["flow_sde", "dance_sde"]), st.integers(2, 10),
       st.integers(0, 2**31 - 1))
@settings(**SET)
def test_sde_eta_zero_matches_ode_trajectory(name, steps, seed):
    """η=0 collapses every SDE scheduler onto the deterministic flow: the
    full trajectory (and zero log-probs) must match the ODE scheduler's
    under the same key — the paper's 'one knob' degeneracy claim."""
    from repro.core.rollout import rollout
    adapter = _LinearAdapter()
    key = jax.random.PRNGKey(seed)
    cond = jax.random.normal(jax.random.fold_in(key, 1), (2, 2, 5))
    t_sde = rollout(adapter, _LIN_PARAMS, cond, key,
                    build_sched(name, 0.0), steps)
    t_ode = rollout(adapter, _LIN_PARAMS, cond, key,
                    build_sched("ode", 0.0), steps)
    np.testing.assert_allclose(np.asarray(t_sde.xs), np.asarray(t_ode.xs),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(t_sde.logps), 0.0)


@given(st.sampled_from(["flow_sde", "dance_sde"]), st.integers(2, 8),
       st.integers(0, 2**31 - 1))
@settings(**SET)
def test_keyed_rollout_eta_zero_and_batch_invariance(name, steps, seed):
    """rollout_keyed: η=0 matches ODE, and any sub-batch of (cond, keys)
    rows is bit-identical to the same rows in the full batch — the serving
    engine's bucketing/sharding invariant."""
    from repro.core.rollout import request_keys, rollout_keyed
    adapter = _LinearAdapter()
    key = jax.random.PRNGKey(seed)
    cond = jax.random.normal(jax.random.fold_in(key, 1), (4, 2, 5))
    keys = request_keys(key, 4)
    t_sde = rollout_keyed(adapter, _LIN_PARAMS, cond, keys,
                          build_sched(name, 0.0), steps)
    t_ode = rollout_keyed(adapter, _LIN_PARAMS, cond, keys,
                          build_sched("ode", 0.0), steps)
    np.testing.assert_allclose(np.asarray(t_sde.xs), np.asarray(t_ode.xs),
                               atol=1e-6, rtol=1e-6)
    lo, hi = seed % 3, seed % 3 + 2
    sub = rollout_keyed(adapter, _LIN_PARAMS, cond[lo:hi], keys[lo:hi],
                        build_sched(name, 0.0), steps)
    np.testing.assert_array_equal(np.asarray(t_sde.xs[:, lo:hi]),
                                  np.asarray(sub.xs))


@given(st.integers(1, 24), st.integers(0, 30), st.integers(0, 60))
@settings(**SET)
def test_mix_sde_mask_window_shift_invariants(num_steps, window, shift):
    """MixGRPO's sliding SDE window: popcount is min(window, num_steps),
    shifting rolls the mask cyclically, and the extremes degenerate to
    all-ODE / all-SDE."""
    from repro.core.rollout import mix_sde_mask
    m = np.asarray(mix_sde_mask(num_steps, window, shift))
    assert m.shape == (num_steps,) and m.dtype == bool
    assert m.sum() == min(window, num_steps)
    base = np.asarray(mix_sde_mask(num_steps, window, 0))
    np.testing.assert_array_equal(m, np.roll(base, shift % num_steps))
    assert not np.asarray(mix_sde_mask(num_steps, 0, shift)).any()
    assert np.asarray(mix_sde_mask(num_steps, num_steps, shift)).all()


@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_group_repeat_round_trips(P, G, seed):
    """(P, Lc, D) -> (P·G, Lc, D): group g of prompt p occupies rows
    p·G..p·G+G−1, every group row equals its prompt, and striding / group
    reshape both recover the original."""
    from repro.core.rollout import group_repeat
    cond = jax.random.normal(jax.random.PRNGKey(seed), (P, 3, 2))
    g = group_repeat(cond, G)
    assert g.shape == (P * G, 3, 2)
    grouped = np.asarray(g).reshape(P, G, 3, 2)
    np.testing.assert_array_equal(grouped,
                                  np.broadcast_to(np.asarray(cond)[:, None],
                                                  (P, G, 3, 2)))
    np.testing.assert_array_equal(np.asarray(g[::G]), np.asarray(cond))
    np.testing.assert_array_equal(np.asarray(group_repeat(cond, 1)),
                                  np.asarray(cond))
