"""Hypothesis property tests over the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import registry
from repro.core.rewards import group_normalize
from repro.core.schedulers import build as build_sched
from repro.kernels import ref

SET = dict(max_examples=25, deadline=None)


@given(st.integers(1, 6), st.integers(2, 8), st.floats(0.1, 10.0),
       st.floats(-5.0, 5.0), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_group_normalize_invariants(groups, gsize, scale, shift, seed):
    """Group-normalized advantages: zero group mean; invariant to per-group
    affine reward transforms (the GRPO scale-robustness property)."""
    r = jax.random.normal(jax.random.PRNGKey(seed), (groups * gsize,))
    z1 = group_normalize(r, gsize)
    z2 = group_normalize(r * scale + shift, gsize)
    np.testing.assert_allclose(np.asarray(z1.reshape(groups, gsize).mean(1)),
                               0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), atol=1e-3)


@given(st.floats(0.01, 0.5), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_ratio_clip_bounds(clip, seed):
    """Clipped objective is bounded by |adv|·(1+clip) wherever the advantage
    is positive (the PPO pessimism property)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    lpn = jax.random.normal(k1, (64,))
    lpo = jax.random.normal(k2, (64,))
    adv = jnp.abs(jax.random.normal(k3, (64,)))
    loss, _ = ref.grpo_loss_ref(lpn, lpo, adv, clip=clip)
    assert bool(jnp.all(-loss <= adv * (1.0 + clip) + 1e-5))


@given(st.sampled_from(["flow_sde", "dance_sde", "cps"]),
       st.floats(0.1, 0.9), st.integers(2, 16), st.integers(0, 2**31 - 1))
@settings(**SET)
def test_scheduler_logprob_consistency(name, eta, steps, seed):
    s = build_sched(name, eta)
    ts = s.timesteps(steps)
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (2, 4))
    v = jax.random.normal(k2, (2, 4)) * 0.5
    i = seed % steps
    x_next, lp = s.step(v, x, ts[i], ts[i + 1], k3)
    lp2 = s.logprob(v, x, ts[i], ts[i + 1], x_next)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lp2),
                               rtol=1e-4, atol=1e-3)
    assert bool(jnp.all(jnp.isfinite(x_next)))


@given(st.integers(1, 3), st.integers(1, 4), st.integers(8, 32),
       st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ssd_scan_chaining_property(B, H, L2, seed):
    """Chunked SSD over [a; b] == scan(a) then scan(b, init=state(a)) — the
    invariant sequence-parallel sharding relies on."""
    L = 2 * L2
    P, N = 8, 16
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, L, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.2)
    bm = jax.random.normal(ks[3], (B, L, N)) * 0.3
    cm = jax.random.normal(ks[4], (B, L, N)) * 0.3
    y_full, h_full = ref.ssd_scan_ref(x, dt, a, bm, cm)
    y1, h1 = ref.ssd_scan_ref(x[:, :L2], dt[:, :L2], a, bm[:, :L2],
                              cm[:, :L2])
    y2, h2 = ref.ssd_scan_ref(x[:, L2:], dt[:, L2:], a, bm[:, L2:],
                              cm[:, L2:], init_state=h1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               atol=1e-3, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full[:, L2:]),
                               atol=1e-3, rtol=1e-2)


@given(st.integers(0, 2**31 - 1), st.integers(1, 64))
@settings(max_examples=10, deadline=None)
def test_rope_relative_position_property(seed, shift):
    """RoPE attention scores depend only on relative positions: shifting all
    positions by a constant leaves q·k unchanged."""
    from repro.models.layers import apply_rope
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    q = jax.random.normal(k1, (1, 8, 2, 32))
    k = jax.random.normal(k2, (1, 8, 2, 32))
    pos = jnp.arange(8)
    s0 = jnp.einsum("bshd,bthd->bsth", apply_rope(q, pos, 1e4),
                    apply_rope(k, pos, 1e4))
    s1 = jnp.einsum("bshd,bthd->bsth", apply_rope(q, pos + shift, 1e4),
                    apply_rope(k, pos + shift, 1e4))
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), atol=1e-3)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_registry_idempotent_lookup(seed):
    for kind in ("trainer", "scheduler", "reward", "aggregator"):
        for name in registry.names(kind):
            assert registry.lookup(kind, name) is registry.lookup(kind, name)
