"""End-to-end behaviour tests for the paper's system: full preprocessing →
rollout → multi-reward → update pipeline, and the dry-run/roofline path on a
small host mesh (subprocess — device count must be set before jax init)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, registry
from repro.config import FlowRLConfig, OptimConfig, RewardSpec
from repro.core.preprocess import (ConditionProvider, FrozenTextEncoder,
                                   PreprocessCache, preprocess_dataset)
from repro.data import PromptDataset, synthetic_prompts

KEY = jax.random.PRNGKey(0)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_full_pipeline_end_to_end(tmp_path):
    """The paper's workflow: preprocess prompts once (frozen encoder then
    offloaded), train GRPO on cached conditions with two deduplicated
    rewards, reward improves."""
    prompts = synthetic_prompts(8)
    cache = PreprocessCache(str(tmp_path))
    enc_kw = dict(cond_dim=512, cond_len=4, vocab=512, hidden=64)
    preprocess_dataset(prompts, cache, encoder=FrozenTextEncoder(**enc_kw))
    provider = ConditionProvider(preprocessing=True, cache=cache)

    flow = FlowRLConfig(
        num_steps=4, group_size=4, latent_tokens=8, latent_dim=8,
        advantage_agg="gdpo",
        rewards=(RewardSpec("text_render", 1.0,
                            args={"latent_dim": 8, "latent_tokens": 8}),
                 RewardSpec("pickscore", 0.2, model_id="ps",
                            args={"latent_dim": 8}),
                 RewardSpec("pref_group", 0.2, model_id="ps",
                            args={"latent_dim": 8})))
    trainer = registry.build(
        "trainer", "flow_grpo", configs.get_reduced("flux_dit"), flow,
        OptimConfig(lr=3e-4, total_steps=40, warmup_steps=2), key=KEY)
    assert trainer.loader.unique_loads == 2      # dedup across 3 specs

    ds = PromptDataset(prompts, batch_size=4)
    rewards = []
    for it, batch_prompts in zip(range(16), ds.infinite()):
        cond = provider.get(batch_prompts)["cond"]
        m = trainer.step(cond, KEY, it=it)
        rewards.append(float(m["reward_mean"]))
    assert not provider.encoder_resident          # offload held throughout
    assert np.mean(rewards[-4:]) > np.mean(rewards[:4]), rewards


def test_trainer_switch_is_config_only():
    """Paper §4.2: switching trainer_type in config is the ONLY change
    needed to run a different algorithm on the same backbone + rewards."""
    arch_cfg = configs.get_reduced("flux_dit")
    flow_cfg = FlowRLConfig(num_steps=3, group_size=2, latent_tokens=8,
                            latent_dim=8)
    opt_cfg = OptimConfig(total_steps=4)
    for tname in ("flow_grpo", "mix_grpo", "grpo_guard", "nft", "awm"):
        tr = registry.build("trainer", tname, arch_cfg, flow_cfg, opt_cfg,
                            key=KEY)
        m = tr.step(jax.random.normal(KEY, (2, 4, 512)), KEY, it=0)
        assert jnp.isfinite(m["loss"]), tname


def test_dryrun_small_mesh_subprocess(tmp_path):
    """The dry-run machinery works end-to-end on a small host mesh: lower +
    compile + memory/collective analysis."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro import configs
from repro.config import InputShape
from repro.launch.specs import build_step
from repro.launch import hlo_stats
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg = configs.get_reduced("qwen3-32b")
shape = InputShape("t", 128, 8, "train")
with mesh:
    fn, args = build_step(cfg, shape, mesh)
    compiled = fn.lower(*args).compile()
coll = hlo_stats.collective_bytes(compiled.as_text())
assert coll["_total"]["count"] > 0, coll
mem = compiled.memory_analysis()
assert mem.argument_size_in_bytes > 0
print("SUBPROCESS_OK", coll["_total"]["count"])
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH":
                            os.path.join(REPO, "src")})
    assert "SUBPROCESS_OK" in r.stdout, r.stderr[-2000:]


def test_dryrun_decode_small_mesh_subprocess():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro import configs
from repro.config import InputShape
from repro.launch.specs import build_step
mesh = jax.make_mesh((4, 2), ("data", "model"))
for arch in ("mamba2-370m", "zamba2-2.7b", "deepseek-v2-236b"):
    cfg = configs.get_reduced(arch)
    shape = InputShape("d", 256, 8, "decode")
    with mesh:
        fn, args = build_step(cfg, shape, mesh)
        fn.lower(*args).compile()
print("SUBPROCESS_OK")
"""
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH":
                            os.path.join(REPO, "src")})
    assert "SUBPROCESS_OK" in r.stdout, r.stderr[-2000:]


def test_costs_model_consistency():
    """Analytic cost model sanity: train > prefill > decode FLOPs; MoE
    active ≪ total; long-context decode uses the window."""
    from repro.launch import costs
    from repro.config import INPUT_SHAPES
    cfg = configs.get("yi-9b")
    tr = costs.step_costs(cfg, INPUT_SHAPES["train_4k"])
    pf = costs.step_costs(cfg, INPUT_SHAPES["prefill_32k"])
    dc = costs.step_costs(cfg, INPUT_SHAPES["decode_32k"])
    assert tr.flops > pf.flops > dc.flops
    assert tr.flops_kernel < tr.flops          # causal skipping helps
    moe = configs.get("deepseek-v2-236b")
    assert moe.n_active_params() < 0.2 * moe.n_params()
    lk = costs.step_costs(configs.get("yi-34b"), INPUT_SHAPES["long_500k"])
    assert "window" in lk.notes


def test_hlo_stats_trip_count_expansion():
    """Collectives inside a scanned body are multiplied by the trip count."""
    from repro.launch import hlo_stats
    hlo = """
HloModule test

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8]{0} all-reduce(f32[8]{0} %x), replica_groups=[1,4]<=[4]
}

%cond (p: (s32[], f32[8])) -> pred[] {
  %c = s32[] constant(12)
  %cmp = pred[] compare(s32[] %i, s32[] %c), direction=LT
}

ENTRY %main () -> f32[8] {
  %w = (s32[], f32[8]) while((s32[], f32[8]) %init), condition=%cond, body=%body
  %ag = f32[16]{0} all-gather(f32[8]{0} %y), replica_groups=[2,2]<=[4]
}
"""
    coll = hlo_stats.collective_bytes(hlo)
    assert coll["all-reduce"]["count"] == 12
    assert coll["all-gather"]["count"] == 1
    assert coll["all-reduce"]["result_bytes"] == 12 * 32
