"""SDE scheduler unit tests (paper Table 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import registry
from repro.core import schedulers

KEY = jax.random.PRNGKey(11)
ALL = ["flow_sde", "dance_sde", "cps", "ode"]


@pytest.mark.parametrize("name", ALL)
def test_registered_and_buildable(name):
    s = schedulers.build(name, eta=0.5)
    ts = s.timesteps(8)
    assert ts.shape == (9,)
    assert bool(jnp.all(ts[:-1] > ts[1:]))           # descending
    assert float(ts[0]) <= 1.0 and float(ts[-1]) >= 0.0


@pytest.mark.parametrize("name", ["flow_sde", "dance_sde", "cps"])
def test_logprob_matches_step_sample(name):
    """log p(x_next | x) recomputed equals the density of the transition the
    sampler actually took (the GRPO ratio=1 identity at rollout params)."""
    s = schedulers.build(name, eta=0.5)
    x = jax.random.normal(KEY, (4, 8))
    v = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    t, t_next = jnp.float32(0.7), jnp.float32(0.6)
    x_next, logp = s.step(v, x, t, t_next, jax.random.PRNGKey(2))
    logp2 = s.logprob(v, x, t, t_next, x_next)
    np.testing.assert_allclose(logp, logp2, rtol=1e-5, atol=1e-4)


def test_ode_is_deterministic_and_matches_euler():
    s = schedulers.build("ode", eta=0.0)
    x = jax.random.normal(KEY, (3, 5))
    v = jax.random.normal(jax.random.PRNGKey(1), (3, 5))
    t, t_next = jnp.float32(0.5), jnp.float32(0.4)
    x1, lp = s.step(v, x, t, t_next, jax.random.PRNGKey(2))
    x2, _ = s.step(v, x, t, t_next, jax.random.PRNGKey(99))
    np.testing.assert_allclose(x1, x2)               # key-independent
    np.testing.assert_allclose(x1, x - v * (t - t_next), rtol=1e-6)
    np.testing.assert_allclose(lp, 0.0)


def test_flow_sde_sigma_shape():
    s = schedulers.build("flow_sde", eta=0.7)
    # σ grows toward t=1 (exploration early in sampling)
    assert float(s.sigma(0.9, 0.8)) > float(s.sigma(0.2, 0.1))
    np.testing.assert_allclose(float(s.sigma(0.5, 0.4)), 0.7, rtol=1e-5)


def test_dance_sigma_constant():
    s = schedulers.build("dance_sde", eta=0.3)
    assert float(s.sigma(0.9, 0.8)) == pytest.approx(0.3)
    assert float(s.sigma(0.1, 0.05)) == pytest.approx(0.3)


def test_cps_preserves_marginal_coefficients():
    """CPS: with exact rectified-flow inputs (x_t = (1-t)x0 + t·eps and the
    true velocity), the sampled x_next keeps the marginal decomposition
    (1-t')x0 + t'·(unit-variance noise) — coefficients preserved."""
    s = schedulers.build("cps", eta=0.5)
    n = 20000
    k1, k2, k3 = jax.random.split(KEY, 3)
    x0 = jax.random.normal(k1, (n, 1)) * 0.0 + 1.0   # constant data point
    eps = jax.random.normal(k2, (n, 1))
    t, t_next = jnp.float32(0.7), jnp.float32(0.5)
    x_t = (1 - t) * x0 + t * eps
    v = eps - x0                                      # true velocity
    x_next, _ = s.step(v, x_t, t, t_next, k3)
    noise = (x_next - (1 - t_next) * x0) / t_next
    assert abs(float(noise.mean())) < 0.02
    assert abs(float(noise.std()) - 1.0) < 0.02


def test_mixed_mask_zeroes_ode_logps():
    from repro.core.rollout import mix_sde_mask
    m = mix_sde_mask(10, 2, shift=0)
    assert m.sum() == 2 and bool(m[0]) and bool(m[1]) and not bool(m[2])
    m2 = mix_sde_mask(10, 2, shift=3)
    assert bool(m2[3]) and bool(m2[4]) and m2.sum() == 2
