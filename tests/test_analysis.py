"""repro.analysis (jaxlint) — the static analyzer that encodes this repo's
JAX bug classes as checkable rules.

Fixture pairs per rule (positive MUST flag with the right rule id,
negative MUST stay clean), including source-level reconstructions of the
two incidents that motivated the linter:

* the PR-2 NFT bug — a jitted loss reading ``self.ref_params`` that
  ``update_extras`` mutates between rounds (R003 mutable-closure-capture);
* the PR-5 perf bug — per-metric ``float()`` host syncs inside the train
  step loop (R002 host-sync-in-hot-loop).

Plus the meta self-run: ``src/repro`` + ``benchmarks`` + ``examples`` must
be clean modulo the committed baseline, so a future PR reintroducing
either class fails tier-1; and the stdlib-only contract: importing
``repro.analysis`` must not pull in jax or numpy.
"""
import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis import ScopeGraph, rule_ids
from repro.analysis.core import Module, parse_suppressions
from repro.analysis import baseline as bl
from repro.analysis.cli import main as cli_main, run_paths

ROOT = Path(__file__).resolve().parent.parent


def lint(tmp_path: Path, source: str, name: str = "mod.py"):
    """Write one fixture module, lint it, return reportable findings."""
    f = tmp_path / name
    f.write_text(source)
    findings, suppressed, graph = run_paths([str(f)])
    return findings


def rules_of(findings):
    return sorted({f.rule for f in findings})


# ------------------------------------------------------------------- R001

def test_r001_key_reuse_flagged(tmp_path):
    findings = lint(tmp_path, """\
import jax

def sample(key):
    a = jax.random.normal(key, (3,))
    b = jax.random.uniform(key, (3,))
    return a + b
""")
    assert rules_of(findings) == ["R001"]
    assert len(findings) == 1           # only the second consumption


def test_r001_split_then_use_clean(tmp_path):
    findings = lint(tmp_path, """\
import jax

def sample(key):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (3,))
    b = jax.random.uniform(k2, (3,))
    return a + b
""")
    assert findings == []


def test_r001_exclusive_return_branches_clean(tmp_path):
    # the sample_timesteps shape: each branch consumes the key once and
    # returns — never two consumptions on one path
    findings = lint(tmp_path, """\
import jax

def sample(key, how):
    if how == "uniform":
        return jax.random.uniform(key, (3,))
    if how == "normal":
        return jax.random.normal(key, (3,))
    return jax.random.bernoulli(key, 0.5, (3,))
""")
    assert findings == []


def test_r001_loop_reuse_flagged_fold_in_clean(tmp_path):
    flagged = lint(tmp_path, """\
import jax

def noisy(key, n):
    out = []
    for i in range(n):
        out.append(jax.random.normal(key, (3,)))
    return out
""")
    assert rules_of(flagged) == ["R001"]
    clean = lint(tmp_path, """\
import jax

def noisy(key, n):
    out = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        out.append(jax.random.normal(k, (3,)))
    return out
""", name="clean.py")
    assert clean == []


# ------------------------------------------------------------------- R002

PR5_SYNC_LOOP = """\
import jax

def run(trainer, steps):
    history = []
    for it in range(steps):
        m = jax.device_get(trainer.step(it))
        history.append({
            "reward": float(m["reward_mean"]),
            "loss": float(m["loss"]),
            "grad_norm": float(m["grad_norm"]),
        })
    return history
"""


def test_r002_pr5_per_metric_sync_loop_flagged(tmp_path):
    """Reconstruction of the PR-5 incident: metrics arrive via ONE
    device_get but are then float()ed per value inside the step loop."""
    findings = lint(tmp_path, PR5_SYNC_LOOP)
    assert rules_of(findings) == ["R002"]
    assert len(findings) == 3           # one per float()


def test_r002_convert_at_transfer_site_clean(tmp_path):
    # the PR-5 fix shape: one device_get, tree-mapped to float once
    findings = lint(tmp_path, """\
import jax

def run(trainer, steps):
    history = []
    for it in range(steps):
        m = jax.tree.map(float, jax.device_get(trainer.step(it)))
        history.append({"reward": m["reward_mean"], "loss": m["loss"]})
    return history
""")
    assert findings == []


def test_r002_sync_on_fresh_device_compute_flagged(tmp_path):
    # the serve.py:88 shape — flagged even outside a loop
    findings = lint(tmp_path, """\
import jax.numpy as jnp

def report(latents):
    return float(jnp.sqrt((latents ** 2).mean()))
""")
    assert rules_of(findings) == ["R002"]


def test_r002_host_floats_clean(tmp_path):
    findings = lint(tmp_path, """\
def run(rows):
    out = []
    for r in rows:
        out.append({"a": float(r["a"]), "b": float(r["b"])})
    return out
""")
    assert findings == []


# ------------------------------------------------------------------- R003

PR2_NFT_CAPTURE = """\
import jax

class Trainer:
    def __init__(self):
        self.ref_params = {"w": 1.0}
        self._update_jit = jax.jit(self.loss_fn)

    def update_extras(self):
        self.ref_params = {"w": 2.0}   # refresh the reference policy

    def loss_fn(self, params):
        ref = self.ref_params
        return params["w"] - ref["w"]
"""


def test_r003_pr2_nft_closure_capture_flagged(tmp_path):
    """Reconstruction of the PR-2 incident: the jitted loss closes over
    ``self.ref_params``, which ``update_extras`` mutates between rounds —
    the traced constant never sees the refresh (flat reward curve)."""
    findings = lint(tmp_path, PR2_NFT_CAPTURE)
    assert rules_of(findings) == ["R003"]
    (f,) = findings
    assert "ref_params" in f.message and "update_extras" in f.message


def test_r003_init_only_attr_clean(tmp_path):
    findings = lint(tmp_path, """\
import jax

class Trainer:
    def __init__(self):
        self.scale = 2.0
        self._fn = jax.jit(self.loss_fn)

    def loss_fn(self, params):
        return params["w"] * self.scale
""")
    assert findings == []


def test_r003_nonlocal_rebind_after_def_flagged(tmp_path):
    findings = lint(tmp_path, """\
import jax

def build(scale):
    def body(x):
        return x * scale
    scale = scale * 2
    return jax.jit(body)
""")
    assert rules_of(findings) == ["R003"]


# ------------------------------------------------------------------- R004

def test_r004_branch_on_tracer_flagged(tmp_path):
    findings = lint(tmp_path, """\
import jax
import jax.numpy as jnp

@jax.jit
def clip(x):
    y = jnp.sum(x)
    if y > 0:
        return x
    return -x
""")
    assert rules_of(findings) == ["R004"]


def test_r004_static_branches_clean(tmp_path):
    # config-style branching on plain params / shapes is static and fine
    findings = lint(tmp_path, """\
import jax
import jax.numpy as jnp

@jax.jit
def f(x, mode="a"):
    if mode == "a":
        return jnp.tanh(x)
    if x.shape[0] > 4:
        return x[:4]
    return x
""")
    assert findings == []


def test_r004_untraced_function_clean(tmp_path):
    # host code may branch on concrete array values freely
    findings = lint(tmp_path, """\
import jax.numpy as jnp

def early_stop(history):
    v = jnp.asarray(history)
    if v.sum() > 0:
        return True
    return False
""")
    assert findings == []


# ------------------------------------------------------------------- R005

def test_r005_read_after_donate_flagged(tmp_path):
    findings = lint(tmp_path, """\
import jax

def train(step_fn, state, batch):
    step = jax.jit(step_fn, donate_argnums=0)
    new_state = step(state, batch)
    return new_state, state["metrics"]
""")
    assert rules_of(findings) == ["R005"]


def test_r005_reassign_result_clean(tmp_path):
    # the repo idiom: the donated buffer is immediately reassigned
    findings = lint(tmp_path, """\
import jax

def train(step_fn, state, batch):
    step = jax.jit(step_fn, donate_argnums=0)
    state = step(state, batch)
    return state["metrics"]
""")
    assert findings == []


# ------------------------------------------------------------------- R006

def test_r006_unhashable_static_arg_flagged(tmp_path):
    findings = lint(tmp_path, """\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("cfg",))
def run(x, cfg):
    return x

def driver(x):
    return run(x, cfg={"width": 8})
""")
    assert rules_of(findings) == ["R006"]


def test_r006_hashable_static_arg_clean(tmp_path):
    findings = lint(tmp_path, """\
import functools
import jax

@functools.partial(jax.jit, static_argnames=("cfg",))
def run(x, cfg):
    return x

def driver(x):
    return run(x, cfg=("width", 8))
""")
    assert findings == []


def test_r006_jit_in_loop_flagged(tmp_path):
    findings = lint(tmp_path, """\
import jax

def sweep(fns, x):
    outs = []
    for fn in fns:
        outs.append(jax.jit(fn)(x))
    return outs
""")
    assert rules_of(findings) == ["R006"]


# ------------------------------------------------------------------- R007

def test_r007_pre_pipeline_loop_flagged(tmp_path):
    # source-level reconstruction of the pre-pipeline TrainLoop: dispatch a
    # jitted step, then immediately device_get its metrics in the same
    # iteration — the drain blocks the next dispatch
    findings = lint(tmp_path, """\
import jax

class Loop:
    def __init__(self, fn, state):
        self._step_jit = jax.jit(fn)
        self.state = state

    def run(self, batches, key):
        history = []
        for it, batch in enumerate(batches):
            self.state, metrics = self._step_jit(self.state, batch, key)
            m = jax.device_get(metrics)
            history.append(m)
        return history
""")
    assert rules_of(findings) == ["R007"]
    assert len(findings) == 1


def test_r007_float_on_dispatched_output_flagged(tmp_path):
    findings = lint(tmp_path, """\
import jax

def run(step, xs, state):
    step_jit = jax.jit(step)
    losses = []
    for x in xs:
        state, loss = step_jit(state, x)
        losses.append(float(loss))
    return losses
""")
    assert rules_of(findings) == ["R007"]


def test_r007_lagged_deque_drain_clean(tmp_path):
    # the pipelined shape this PR's TrainLoop uses: buffer the in-flight
    # step's outputs and drain them >=1 step late / after the loop
    findings = lint(tmp_path, """\
import collections
import jax

def run(step, xs, state):
    step_jit = jax.jit(step)
    pending = collections.deque()
    out = []
    for x in xs:
        state, loss = step_jit(state, x)
        pending.append(loss)
        if len(pending) > 1:
            out.append(float(jax.device_get(pending.popleft())))
    while pending:
        out.append(float(jax.device_get(pending.popleft())))
    return out
""")
    assert findings == []


def test_r007_drain_after_loop_clean(tmp_path):
    findings = lint(tmp_path, """\
import jax

def run(step, xs, state):
    step_jit = jax.jit(step)
    losses = []
    for x in xs:
        state, loss = step_jit(state, x)
        losses.append(loss)
    return [float(v) for v in jax.device_get(losses)]
""")
    assert findings == []


# ----------------------------------------------------- suppressions / R000

def test_suppression_with_reason_honored(tmp_path):
    findings = lint(tmp_path, """\
import jax

def sample(key):
    a = jax.random.normal(key, (3,))
    # jaxlint: disable=R001 — deliberate common-random-numbers baseline
    b = jax.random.normal(key, (3,))
    return a + b
""")
    assert findings == []


def test_suppression_without_reason_is_a_finding(tmp_path):
    findings = lint(tmp_path, """\
import jax

def sample(key):
    a = jax.random.normal(key, (3,))
    b = jax.random.normal(key, (3,))  # jaxlint: disable=R001
    return a + b
""")
    # the bare disable= is itself flagged AND does not suppress
    assert rules_of(findings) == ["R000", "R001"]


def test_suppression_in_docstring_is_prose(tmp_path):
    findings = lint(tmp_path, '''\
def helper():
    """Mentions `# jaxlint: disable=R001` as documentation only."""
    return 1
''')
    assert findings == []
    mod = Module.parse(tmp_path / "mod.py")
    assert mod.suppressions == []


def test_multiline_standalone_suppression_covers_next_code_line(tmp_path):
    src = (
        "import jax\n"
        "def sample(key):\n"
        "    a = jax.random.normal(key, (3,))\n"
        "    # jaxlint: disable=R001 — first half of the why,\n"
        "    # wrapped onto a continuation comment line\n"
        "    b = jax.random.normal(key, (3,))\n"
        "    return a + b\n")
    assert lint(tmp_path, src) == []


def test_unknown_rule_id_flagged(tmp_path):
    findings = lint(tmp_path, """\
x = 1  # jaxlint: disable=R999 — no such rule
""")
    assert rules_of(findings) == ["R000"]


def test_list_suppressions_mode(tmp_path, capsys):
    f = tmp_path / "mod.py"
    f.write_text("""\
import jax

def g(key):
    # jaxlint: disable=R001 — audit me
    b = jax.random.normal(key, (3,))
    return b
""")
    rc = cli_main(["--list-suppressions", str(f)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "R001" in out and "audit me" in out and "1 suppression(s)" in out


# -------------------------------------------------------- scope graph unit

def test_wrapper_layer_traces_argument(tmp_path):
    """The distributed.jit_* idiom: passing a function through a wrapper
    whose parameter flows into jax.jit marks it traced."""
    f = tmp_path / "mod.py"
    f.write_text("""\
import jax

def jit_update(fn, mesh):
    return jax.jit(fn, donate_argnums=(0,))

def _update(state, batch):
    return state

def host_side(rows):
    return len(rows)

def build(mesh):
    return jit_update(_update, mesh)
""")
    mod = Module.parse(f)
    graph = ScopeGraph([mod])
    by_name = {fi.name: fi for fi in graph.module_functions(mod)}
    assert graph.is_traced(by_name["_update"])
    assert not graph.is_traced(by_name["host_side"])
    assert not graph.is_traced(by_name["build"])


def test_wrapper_layer_transitive_through_helper(tmp_path):
    """The 2-D distributed.jit_* layering: jit_sample forwards fn into a
    shared _plan_jit helper which calls jax.jit.  The forwarding function
    must itself become a wrapper (its call sites trace the argument), and
    the non-donating helper chain must NOT acquire donation marks."""
    f = tmp_path / "mod.py"
    f.write_text("""\
import jax

def _plan_jit(fn, in_shardings, out_shardings=None):
    kw = {}
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    return jax.jit(fn, in_shardings=in_shardings, **kw)

def jit_sample(fn, mesh, params_sharding=None):
    return _plan_jit(fn, (params_sharding, None), None)

def _sample(params, cond, key):
    return params

def host_side(rows):
    return len(rows)

def build(mesh):
    return jit_sample(_sample, mesh)
""")
    mod = Module.parse(f)
    graph = ScopeGraph([mod])
    by_name = {fi.name: fi for fi in graph.module_functions(mod)}
    # transitive: _sample reaches jax.jit through jit_sample -> _plan_jit
    assert graph.is_traced(by_name["_sample"])
    assert not graph.is_traced(by_name["host_side"])
    # position 0 of both layers is a wrapper position...
    assert 0 in graph.wrapper_positions[id(by_name["jit_sample"].node)]
    assert 0 in graph.wrapper_positions[id(by_name["_plan_jit"].node)]
    # ...and neither layer donates (no donate_argnums anywhere)
    assert id(by_name["jit_sample"].node) not in graph.wrapper_donates
    assert id(by_name["_plan_jit"].node) not in graph.wrapper_donates


def test_wrapper_donation_inherited_through_forwarding(tmp_path):
    """Donation marks propagate up a forwarding chain: a helper whose
    jax.jit passes donate_argnums hands its donated positions to every
    wrapper that forwards a function into it — R005's donated-buffer
    tracking keys off the outermost call site."""
    f = tmp_path / "mod.py"
    f.write_text("""\
import jax

def _donating_jit(fn, shardings):
    return jax.jit(fn, in_shardings=shardings, donate_argnums=(0,))

def jit_update(fn, mesh, state_sharding=None):
    return _donating_jit(fn, (state_sharding, None))

def _update(state, batch):
    return state

def not_forwarding(fn, mesh):
    # fn never reaches a traced position: stays a plain function
    return (fn, mesh)
""")
    mod = Module.parse(f)
    graph = ScopeGraph([mod])
    by_name = {fi.name: fi for fi in graph.module_functions(mod)}
    assert graph.wrapper_donates[id(by_name["_donating_jit"].node)] == {0}
    # inherited by the forwarding layer
    assert graph.wrapper_donates[id(by_name["jit_update"].node)] == {0}
    assert 0 in graph.wrapper_positions[id(by_name["jit_update"].node)]
    # a function that merely receives fn without forwarding it into a
    # traced position is neither wrapper nor donor
    assert id(by_name["not_forwarding"].node) not in graph.wrapper_positions
    assert id(by_name["not_forwarding"].node) not in graph.wrapper_donates


# ---------------------------------------------------------------- baseline

def test_baseline_roundtrip_and_staleness(tmp_path):
    f = tmp_path / "mod.py"
    f.write_text(PR5_SYNC_LOOP)
    findings, _, _ = run_paths([str(f)])
    assert findings
    base_file = tmp_path / "base.json"
    bl.save(base_file, findings)
    base = bl.load(base_file)
    new, matched, stale = bl.split(findings, base)
    assert new == [] and len(matched) == len(findings) and stale == []
    # fingerprints survive a pure line shift
    f.write_text("# a new leading comment\n" + PR5_SYNC_LOOP)
    shifted, _, _ = run_paths([str(f)])
    new, matched, stale = bl.split(shifted, base)
    assert new == [] and len(matched) == len(findings) and stale == []
    # fixing the bug makes the entries stale, not failing
    f.write_text("def run():\n    return []\n")
    fixed, _, _ = run_paths([str(f)])
    new, matched, stale = bl.split(fixed, base)
    assert new == [] and matched == [] and len(stale) == len(findings)


def test_cli_json_format_and_exit_codes(tmp_path, capsys, monkeypatch):
    f = tmp_path / "mod.py"
    f.write_text(PR5_SYNC_LOOP)
    monkeypatch.chdir(tmp_path)
    rc = cli_main(["--format", "json", "--no-baseline", str(f)])
    payload = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert {x["rule"] for x in payload["new"]} == {"R002"}
    # accept into a baseline -> clean exit
    rc = cli_main(["--update-baseline", str(f)])
    capsys.readouterr()
    assert rc == 0
    rc = cli_main([str(f)])
    out = capsys.readouterr().out
    assert rc == 0 and "0 new" in out


# ---------------------------------------------------------- meta self-runs

def test_repo_is_clean_modulo_baseline(monkeypatch, capsys):
    """Any future PR reintroducing a linted bug class fails here."""
    monkeypatch.chdir(ROOT)
    rc = cli_main(["src/repro", "benchmarks", "examples"])
    out = capsys.readouterr().out
    assert rc == 0, f"jaxlint found new violations:\n{out}"


def test_every_rule_has_fixture_coverage():
    covered = {"R000", "R001", "R002", "R003", "R004", "R005", "R006",
               "R007"}
    assert set(rule_ids()) == covered, (
        "new rule registered — add positive/negative fixtures for it in "
        "this file and extend `covered`")


def test_analysis_imports_are_stdlib_only():
    """`python -m repro.analysis` must work with jax/numpy absent."""
    code = (
        "import sys\n"
        "sys.modules['jax'] = None\n"
        "sys.modules['numpy'] = None\n"
        "import repro.analysis\n"
        "import repro.analysis.cli\n"
        "assert sys.modules.get('jax') is None\n"
        "print('ok')\n")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0, proc.stderr
    assert proc.stdout.strip() == "ok"
