"""Experiment front-door tests: native typed config loading, declarative
registry construction, dotted overrides, end-to-end smoke training, and
full-state checkpoint→resume bit-identity."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import registry
from repro.api import Experiment, apply_overrides
from repro.config import (ArchConfig, ConfigError, DataConfig, FlowRLConfig,
                          LoopConfig, OptimConfig, RewardSpec, RunConfig,
                          from_dict, to_dict)

TINY_ENCODER = dict(cond_dim=32, cond_len=4, vocab=256, hidden=64)


def tiny_cfg(tmp_path, steps=2, save_every=0, **loop_kw):
    return RunConfig(
        arch="flux_dit", reduced=True,
        flow=FlowRLConfig(num_steps=2, group_size=2, latent_tokens=4,
                          latent_dim=4, rewards=(),
                          cache_dir=str(tmp_path / "cache")),
        optim=OptimConfig(lr=1e-3, total_steps=8, warmup_steps=1),
        data=DataConfig(n_prompts=8, batch_prompts=2, encoder=TINY_ENCODER),
        loop=LoopConfig(steps=steps, save_every=save_every, log_every=0,
                        ckpt_dir=str(tmp_path / "ckpt"), **loop_kw))


# ---------------------------------------------------------------- from_dict

def test_runconfig_json_roundtrip():
    cfg = RunConfig(
        arch_overrides={"n_layers": 2},
        flow=FlowRLConfig(rewards=(
            RewardSpec("text_render", 1.0, args={"latent_dim": 8}),
            RewardSpec("pickscore", 0.5, model_id="ps-base"))),
        data=DataConfig(encoder=TINY_ENCODER))
    d = json.loads(json.dumps(to_dict(cfg)))
    assert from_dict(RunConfig, d) == cfg


def test_from_dict_unknown_key_strict():
    with pytest.raises(ConfigError, match="unknown key.*'nope'"):
        from_dict(RunConfig, {"nope": 1})


def test_from_dict_nested_error_has_path():
    with pytest.raises(ConfigError, match="optim.lr"):
        from_dict(RunConfig, {"optim": {"lr": "fast"}})


def test_from_dict_optional_nested_dataclass():
    a = from_dict(ArchConfig, {
        "name": "x", "family": "moe", "n_layers": 2, "d_model": 64,
        "n_heads": 4, "n_kv_heads": 2, "d_ff": 128, "vocab_size": 100,
        "moe": {"n_experts": 4, "top_k": 2}})
    assert a.moe.n_experts == 4 and a.frontend.kind == "none"
    assert from_dict(ArchConfig, to_dict(a)) == a


def test_from_dict_missing_required_field():
    with pytest.raises(ConfigError, match="name"):
        from_dict(ArchConfig, {"family": "dense"})


# ---------------------------------------------------- registry construction

def test_build_from_config_spec_forms():
    s1 = registry.build_from_config("scheduler", "ode")
    s2 = registry.build_from_config("scheduler",
                                    {"type": "flow_sde",
                                     "args": {"eta": 0.5}})
    assert s2.eta == 0.5
    assert s1.registry_name == "ode"


def test_build_from_config_validates_args():
    with pytest.raises(registry.RegistryError, match="accepted parameters"):
        registry.build_from_config("scheduler",
                                   {"type": "flow_sde",
                                    "args": {"etaa": 0.5}})
    with pytest.raises(registry.RegistryError, match="spec"):
        registry.build_from_config("scheduler", {"typ": "flow_sde"})


def test_build_from_config_nested_spec():
    @registry.register("aggregator", "nested_spec_probe", override=True)
    def probe(scheduler=None):
        return scheduler

    built = registry.build_from_config(
        "aggregator",
        {"type": "nested_spec_probe",
         "args": {"scheduler": {"kind": "scheduler", "type": "flow_sde",
                                "args": {"eta": 0.25}}}})
    assert built.eta == 0.25          # inner spec built recursively


def test_describe_introspection():
    info = registry.describe("scheduler", "flow_sde")
    assert "eta" in info["params"]
    assert info["params"]["eta"]["required"] is False
    all_trainers = registry.describe("trainer")
    assert "flow_grpo" in all_trainers and "awm" in all_trainers


def test_registry_derived_kinds_present():
    # archs, datasets and optimizers are registry citizens now
    assert "flux_dit" in registry.names("arch")
    assert "smollm-360m" in registry.names("arch")
    assert "synthetic" in registry.names("dataset")
    assert "adamw" in registry.names("optimizer")


# ------------------------------------------------------------ CLI overrides

def test_apply_overrides_typed():
    cfg = RunConfig()
    out = apply_overrides(cfg, ["flow.eta=0.5", "optim.lr=3e-4",
                                "flow.preprocessing=false",
                                "arch=flux_dit", "loop.steps=7"])
    assert out.flow.eta == 0.5 and out.optim.lr == 3e-4
    assert out.flow.preprocessing is False
    assert out.arch == "flux_dit" and out.loop.steps == 7
    # JSON values for structured fields
    out = apply_overrides(cfg, [
        'flow.rewards=[{"reward_type": "latent_norm", "weight": 0.1}]'])
    assert out.flow.rewards == (RewardSpec("latent_norm", 0.1),)


def test_apply_overrides_unknown_field():
    with pytest.raises(ConfigError, match="valid fields"):
        apply_overrides(RunConfig(), ["flow.etaa=0.5"])


def test_from_cli_choices_and_overrides():
    exp = Experiment.from_cli(["--reduced", "--trainer", "awm",
                               "--sde", "ode", "--steps", "3",
                               "--set", "flow.eta=0.1"])
    assert exp.cfg.reduced is True
    assert exp.cfg.flow.trainer_type == "awm"
    assert exp.cfg.flow.sde_type == "ode"
    assert exp.cfg.loop.steps == 3 and exp.cfg.optim.total_steps == 3
    assert exp.cfg.flow.eta == 0.1
    # convenience-flag choices come from the registry, not a literal list
    parser = Experiment.cli_parser()
    trainer_action = next(a for a in parser._actions
                          if a.dest == "trainer")
    assert tuple(trainer_action.choices) == registry.names("trainer")


# ------------------------------------------------------------------- smoke

def test_experiment_smoke_train(tmp_path):
    exp = Experiment.from_config(tiny_cfg(tmp_path, steps=2))
    result = exp.train()
    assert len(result["history"]) == 2
    for row in result["history"]:
        assert np.isfinite(row["reward"]) and np.isfinite(row["loss"])
    # preprocessing kept the frozen encoder offloaded
    assert result["history"][-1]["encode_resident"] is False


def test_experiment_reward_args_autocompleted(tmp_path):
    cfg = tiny_cfg(tmp_path)
    cfg = apply_overrides(cfg, [
        'flow.rewards=[{"reward_type": "text_render"}]'])
    exp = Experiment.from_config(cfg)
    spec = exp.flow.rewards[0]
    assert spec.args["latent_dim"] == 4 and spec.args["latent_tokens"] == 4
    assert spec.args["cond_dim"] == 32


def test_experiment_serve(tmp_path):
    exp = Experiment.from_config(tiny_cfg(tmp_path))
    lat = exp.serve(["a fox in watercolor", "a robot at golden hour"],
                    max_batch=2)
    assert lat.shape == (2, 4, 4)
    assert np.isfinite(np.asarray(lat)).all()


def test_serve_uses_trained_params(tmp_path):
    exp = Experiment.from_config(tiny_cfg(tmp_path, steps=2))
    exp.train()
    sampler = exp.build_sampler()
    trained = jax.tree.leaves(exp.build_trainer().state.params)
    served = jax.tree.leaves(sampler.params)
    assert any(np.asarray(a.astype(jnp.float32)).sum()
               == np.asarray(b.astype(jnp.float32)).sum()
               for a, b in zip(trained, served))
    # and they are literally the same arrays, not a fresh init
    assert served[0] is trained[0]


# ------------------------------------------------------------- JSON log sink

class _Boom(Exception):
    pass


class _CrashAt:
    """Callback that simulates a preemption after ``step`` completes."""

    def __init__(self, step):
        self.step = step

    def on_train_start(self, loop):
        pass

    def on_step(self, loop, step, metrics):
        if step == self.step:
            raise _Boom

    def on_train_end(self, loop, history):
        pass


def test_json_log_survives_crash(tmp_path):
    """Incremental flush: a run killed mid-training keeps every step it
    logged (previously the log was only written at on_train_end, so a crash
    lost the whole history even though checkpoints were saved)."""
    log = tmp_path / "log.json"
    cfg = tiny_cfg(tmp_path, steps=4, save_every=2, log_file=str(log))
    with pytest.raises(_Boom):
        Experiment.from_config(cfg).train(callbacks=[_CrashAt(1)])
    rows = json.loads(log.read_text())
    assert [r["step"] for r in rows] == [0, 1]
    assert all(np.isfinite(r["reward"]) for r in rows)


def test_json_log_resume_merge(tmp_path):
    """Resume-aware merge: after crash + resume the log covers every step
    exactly once; a resume with nothing to do leaves the log untouched."""
    log = tmp_path / "log.json"
    cfg4 = tiny_cfg(tmp_path, steps=4, save_every=2, log_file=str(log))
    with pytest.raises(_Boom):
        Experiment.from_config(cfg4).train(callbacks=[_CrashAt(2)])
    assert [r["step"] for r in json.loads(log.read_text())] == [0, 1, 2]
    result = Experiment.from_config(cfg4).train()      # resumes at step 2
    assert result["start_step"] == 2
    assert [r["step"] for r in json.loads(log.read_text())] == [0, 1, 2, 3]
    # nothing left to do: log stays as-is
    before = log.read_text()
    Experiment.from_config(cfg4).train()
    assert log.read_text() == before


# -------------------------------------------------------- checkpoint/resume

def _state_leaves(state):
    out = []
    for x in jax.tree.leaves(state):
        arr = np.asarray(jax.device_get(x))
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
        out.append(arr)
    return out


def test_checkpoint_resume_bit_identical(tmp_path):
    straight = Experiment.from_config(
        tiny_cfg(tmp_path / "a", steps=4, save_every=2)).train()
    # interrupted: 2 steps, checkpoint, then a fresh process-equivalent
    # resumes from the saved full RLState and finishes
    Experiment.from_config(tiny_cfg(tmp_path / "b", steps=2,
                                    save_every=2)).train()
    resumed = Experiment.from_config(
        tiny_cfg(tmp_path / "b", steps=4, save_every=2)).train()
    assert resumed["start_step"] == 2
    la, lb = _state_leaves(straight["state"]), _state_leaves(resumed["state"])
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(x, y)


def test_resume_restores_optimizer_state(tmp_path):
    exp = Experiment.from_config(tiny_cfg(tmp_path, steps=2, save_every=2))
    exp.train()
    exp2 = Experiment.from_config(tiny_cfg(tmp_path, steps=2, save_every=2))
    result = exp2.train()   # nothing left to do, but state must be restored
    assert result["start_step"] == 2
    assert int(result["state"].opt.step) == 2


def test_resume_refuses_mismatched_config(tmp_path):
    Experiment.from_config(tiny_cfg(tmp_path, steps=2, save_every=2)).train()
    other = apply_overrides(tiny_cfg(tmp_path, steps=4, save_every=2),
                            ["flow.trainer_type=awm"])
    with pytest.raises(ConfigError, match="different experiment"):
        Experiment.from_config(other).train()
    # resume=False into a dir with existing checkpoints would mix runs
    with pytest.raises(ConfigError, match="already contains checkpoints"):
        Experiment.from_config(other).train(resume=False)
    # fresh run works once it stops writing into the foreign ckpt dir
    clean = apply_overrides(other, ["loop.save_every=0"])
    res = Experiment.from_config(clean).train(resume=False)
    assert res["start_step"] == 0
