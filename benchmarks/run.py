# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import json
import sys
import traceback


def main() -> None:
    from benchmarks import preprocessing, reward_curves, roofline, \
        scaling, sde_dynamics, serving, train_step

    suites = [
        ("sde_dynamics (paper Table 1)", sde_dynamics.run),
        ("reward_curves (paper Fig 2)", reward_curves.run),
        ("preprocessing (paper Table 2)", preprocessing.run),
        ("roofline (deliverable g)", roofline.run),
        ("scaling (repro.distributed mesh layouts)", scaling.run),
        ("serving (repro.serving bucketed engine)", serving.run),
        ("train_step (repro.perf remat/fused policies)", train_step.run),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for title, fn in suites:
        try:
            rows = fn()
        except Exception:
            traceback.print_exc()
            failures += 1
            continue
        for row in rows:
            print(f"{row['name']},{row['us_per_call']},"
                  f"\"{json.dumps(row['derived'])}\"")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
