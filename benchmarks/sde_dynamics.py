"""Benchmark: paper Table 1 — the four SDE dynamics under one interface.

For each scheduler: σ(t) profile, per-step log-prob statistics, marginal
agreement with the ODE path (does noise injection preserve the flow
marginals?), and sampling wall time at a fixed backbone.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import FlowRLConfig
from repro.core import schedulers
from repro.core.rollout import rollout
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter

DYNAMICS = [("flow_sde", 0.7), ("dance_sde", 0.3), ("cps", 0.5),
            ("ode", 0.0)]


def run() -> List[Dict]:
    key = jax.random.PRNGKey(0)
    arch = configs.get_reduced("flux_dit")
    flow = FlowRLConfig(num_steps=8, latent_tokens=8, latent_dim=8)
    adapter = FlowAdapter(arch, flow)
    params = params_lib.init(adapter.spec(), key)
    cond = jax.random.normal(key, (16, 4, 512))

    rows = []
    for name, eta in DYNAMICS:
        sched = schedulers.build(name, eta)
        ts = sched.timesteps(flow.num_steps)
        # jaxlint: disable=R007 — one-off per-config setup table, not a
        # steady-state dispatch loop; nothing is in flight to overlap with
        sig = [float(sched.sigma(ts[i], ts[i + 1]))
               for i in range(flow.num_steps)]
        fn = jax.jit(lambda p, c, k, s=sched: rollout(
            adapter, p, c, k, s, flow.num_steps))
        traj = fn(params, cond, key)         # compile
        # jaxlint: disable=R007 — benchmark: the sync IS the measurement
        # (wall-clock per call requires waiting for the device)
        jax.block_until_ready(traj.x0)
        t0 = time.perf_counter()
        traj = fn(params, cond, jax.random.PRNGKey(1))
        # jaxlint: disable=R007 — benchmark: the sync IS the measurement
        jax.block_until_ready(traj.x0)
        dt = (time.perf_counter() - t0) * 1e6
        logps = np.asarray(traj.logps)
        x0 = np.asarray(traj.x0)
        rows.append({
            "name": f"sde_dynamics/{name}",
            "us_per_call": round(dt, 1),
            "derived": {
                "eta": eta,
                "sigma_first": round(sig[0], 4),
                "sigma_last": round(sig[-1], 4),
                "logp_mean": round(float(logps.mean()), 3),
                "logp_std": round(float(logps.std()), 3),
                "x0_rms": round(float(np.sqrt((x0 ** 2).mean())), 3),
                "stochastic": bool(np.any(logps != 0.0)),
            },
        })
    return rows
