"""Benchmark: paper Figure 2 — reward-vs-step curves for Flow-GRPO,
DiffusionNFT and AWM on the same backbone + reward (reproduction of the
published result at CI scale: all three should show consistent reward
growth from the same initialization)."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import numpy as np

from repro import configs, registry
from repro.config import FlowRLConfig, OptimConfig, RewardSpec

ALGOS = ["flow_grpo", "nft", "awm"]
STEPS = 30


def run() -> List[Dict]:
    key = jax.random.PRNGKey(0)
    arch = configs.get_reduced("flux_dit")
    flow = FlowRLConfig(
        num_steps=4, group_size=4, latent_tokens=8, latent_dim=8,
        clip_range=0.2,
        rewards=(RewardSpec("text_render", 1.0,
                            args={"latent_dim": 8, "latent_tokens": 8}),))
    opt = OptimConfig(lr=1e-3, total_steps=STEPS, warmup_steps=2)
    cond = jax.random.normal(key, (4, 4, 512))

    rows = []
    for algo in ALGOS:
        tr = registry.build("trainer", algo, arch, flow, opt, key=key)
        curve = []
        t0 = time.perf_counter()
        for it in range(STEPS):
            m = tr.step(cond, key, it=it)
            curve.append(float(m["reward_mean"]))
        dt = (time.perf_counter() - t0) / STEPS * 1e6
        gain = float(np.mean(curve[-6:]) - np.mean(curve[:6]))
        rows.append({
            "name": f"reward_curves/{algo}",
            "us_per_call": round(dt, 1),
            "derived": {
                "reward_first": round(curve[0], 4),
                "reward_last": round(curve[-1], 4),
                "gain": round(gain, 4),
                "improved": gain > 0,
                "curve": [round(c, 4) for c in curve],
            },
        })
    return rows
