"""Generates the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts.  Run after `python -m repro.launch.sweep --mode dryrun`:

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.roofline import load_records, roofline_row

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def fmt_bytes(b):
    if b is None:
        return "—"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def fmt_s(s):
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s*1e3:.2f}ms"
    return f"{s*1e6:.0f}µs"


def dryrun_table(mesh: str, variant: str = "baseline") -> str:
    rows = ["| arch | shape | devices | compile | peak bytes/dev | "
            "HLO collectives (count / moved bytes per dev) |",
            "|---|---|---|---|---|---|"]
    for rec in load_records(mesh, variant):
        c = rec["collectives"]["_total"]
        rows.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['n_devices']} | "
            f"{rec['compile_s']}s | "
            f"{fmt_bytes(rec['memory'].get('peak_bytes'))} | "
            f"{c['count']:.0f} / {fmt_bytes(c['moved_bytes'])} |")
    return "\n".join(rows)


def roofline_table_md(mesh: str, variant: str) -> str:
    rows = ["| arch | shape | compute | memory | collective | dominant | "
            "useful ratio | MFU bound | fits 16G |",
            "|---|---|---|---|---|---|---|---|---|"]
    for rec in load_records(mesh, variant):
        r = roofline_row(rec)
        if r is None:
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['mfu_upper_bound']:.3f} | "
            f"{'yes' if r['fits_16g'] else 'NO'} |")
    return "\n".join(rows)


def variant_compare(arch: str, shape: str, mesh: str = "pod16x16") -> str:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec["arch"] == arch and rec["shape"] == shape \
                and rec["mesh"] == mesh:
            recs.append(rec)
    rows = ["| variant | collective moved/dev | AR | AG | A2A | "
            "peak bytes/dev |", "|---|---|---|---|---|---|"]
    order = {"baseline": 0, "wg": 1, "wg_bf16": 2, "wg_ep": 3,
             "wg_ep_bf16": 4, "cacheshard": 5, "bf16": 6}
    for rec in sorted(recs, key=lambda r: order.get(r.get("variant"), 99)):
        c = rec["collectives"]
        rows.append(
            f"| {rec.get('variant','baseline')} | "
            f"{fmt_bytes(c['_total']['moved_bytes'])} | "
            f"{fmt_bytes(c['all-reduce']['moved_bytes'])} | "
            f"{fmt_bytes(c['all-gather']['moved_bytes'])} | "
            f"{fmt_bytes(c['all-to-all']['moved_bytes'])} | "
            f"{fmt_bytes(rec['memory'].get('peak_bytes'))} |")
    return "\n".join(rows)


def main() -> None:
    print("## §Dry-run — single pod (16×16)\n")
    print(dryrun_table("pod16x16", "baseline"))
    print("\n## §Dry-run — multi-pod (2×16×16)\n")
    print(dryrun_table("pod2x16x16", "baseline"))
    print("\n## §Roofline — baseline\n")
    print(roofline_table_md("pod16x16", "baseline"))
    print("\n## §Roofline — weight-gathered (optimized)\n")
    print(roofline_table_md("pod16x16", "wg"))
    for arch, shape in (("grok-1-314b", "train_4k"),
                        ("deepseek-v2-236b", "train_4k"),
                        ("deepseek-v2-236b", "decode_32k"),
                        ("flux_dit", "flow_rl_update")):
        print(f"\n## Variants — {arch} × {shape}\n")
        print(variant_compare(arch, shape))


if __name__ == "__main__":
    main()
