"""Benchmark: serving throughput/latency — bucketed engine vs the
historical static serve loop.

The pre-engine ``FlowSampler.serve`` padded *every* chunk to one static
``max_batch`` shape (a remainder of 3 requests cost a full 8-wide rollout).
The engine admits requests into a bucket-tier grid, so remainders run in
the smallest covering bucket, warmup pre-traces the grid, and repeat
prompts skip the encoder.  Rows:

* ``serve_static_loop``  — the old loop (pad-to-max_batch), post-compile
* ``serve_engine``       — engine steady state (post-warmup), same N and
                           max_batch; derived reports speedup vs static
                           (acceptance: >= 1.0) and padding waste
* ``serve_engine_p50``   — single-request latency through the b=1 bucket
* ``serve_multitenant``  — same N through the full admission path (two
                           priority classes, two tenants, weighted-fair
                           dequeue, max_inflight backpressure); derived
                           reports the queue-policy overhead vs the plain
                           engine row (host-side bookkeeping only — the
                           device work is identical)
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import FlowRLConfig
from repro.core.rollout import rollout
from repro.models import params as params_lib
from repro.models.flow import FlowAdapter
from repro.core import schedulers
from repro.serving import AdmissionConfig, PriorityClass, ServingEngine

N_REQUESTS = 20          # deliberately not a multiple of MAX_BATCH: the
MAX_BATCH = 8            # remainder (20 = 2x8 + 4) is where static padding
NUM_STEPS = 6            # wastes a half-empty full-width rollout
REPS = 3                 # best-of (min): shared-CPU wall noise dwarfs the
                         # effect being measured, so means mislead


def _static_loop_serve(fn, params, cond, key, max_batch):
    """The pre-engine FlowSampler.serve: one static (max_batch, ...) shape,
    every chunk padded up to it.  ``fn`` is the jitted rollout, built ONCE
    by the caller so the timed reps hit a warm trace cache."""
    outs = []
    N = cond.shape[0]
    for i in range(0, N, max_batch):
        chunk = cond[i:i + max_batch]
        pad = max_batch - chunk.shape[0]
        if pad:
            chunk = jnp.pad(chunk, ((0, pad), (0, 0), (0, 0)))
        traj = fn(params, chunk, jax.random.fold_in(key, i))
        outs.append(traj.x0[:chunk.shape[0] - pad if pad else None])
    return jnp.concatenate(outs, axis=0)[:N]


def run() -> List[Dict]:
    key = jax.random.PRNGKey(0)
    arch = configs.get_reduced("flux_dit")
    flow = FlowRLConfig(num_steps=NUM_STEPS, latent_tokens=16, latent_dim=8)
    adapter = FlowAdapter(arch, flow)
    params = params_lib.init(adapter.spec(), key)
    scheduler = schedulers.build("ode", 0.0)
    cond = jax.random.normal(key, (N_REQUESTS, 4, 512), jnp.float32)

    # ---- warm both paths ------------------------------------------------
    fn = jax.jit(lambda p, c, k: rollout(adapter, p, c, k, scheduler,
                                         NUM_STEPS))
    jax.block_until_ready(_static_loop_serve(fn, params, cond, key,
                                             MAX_BATCH))
    engine = ServingEngine(adapter, scheduler, params, num_steps=NUM_STEPS,
                           max_batch=MAX_BATCH, cond_len=cond.shape[1])
    warm = engine.warmup()
    jax.block_until_ready(engine.serve(cond, key))

    # ---- interleaved best-of-REPS timing --------------------------------
    static_ts, engine_ts = [], []
    for r in range(REPS):
        t0 = time.perf_counter()
        lat = _static_loop_serve(fn, params, cond, key, MAX_BATCH)
        jax.block_until_ready(lat)
        static_ts.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        lat = engine.serve(cond, jax.random.fold_in(key, r))
        jax.block_until_ready(lat)
        engine_ts.append(time.perf_counter() - t0)
    static_s, engine_s = min(static_ts), min(engine_ts)
    stats = engine.stats
    assert stats["cold_dispatches"] == 0, "engine compiled during timing"

    # ---- single-request latency through the b=1 bucket ------------------
    h = engine.submit(cond=cond[0], seed=123)
    engine.drain()
    jax.block_until_ready(h.result())                         # b=1 warm
    t0 = time.perf_counter()
    for r in range(REPS):
        h = engine.submit(cond=cond[0], seed=200 + r)
        engine.drain()
        jax.block_until_ready(h.result())
    p50_s = (time.perf_counter() - t0) / REPS

    # ---- multi-tenant admission path ------------------------------------
    # the same N requests submitted under two priority classes and two
    # tenants with a bounded in-flight window: measures what the queue
    # policy (stride scheduling + deadline checks + depth accounting)
    # costs on top of the identical device work
    mt = ServingEngine(
        adapter, scheduler, params, num_steps=NUM_STEPS,
        max_batch=MAX_BATCH, cond_len=cond.shape[1], deadline_s=0.0,
        max_inflight=2,
        admission=AdmissionConfig(
            classes=(PriorityClass("interactive", weight=4, max_depth=32),
                     PriorityClass("batch", weight=1, max_depth=256)),
            tenant_weights=(("acme", 2),), default_class="batch"))
    mt.warmup()

    def mt_pass(rep: int):
        handles = [mt.submit(cond=cond[i], seed=rep * 1000 + i,
                             tenant=("acme", "zoo")[i % 2],
                             priority="interactive" if i % 3 == 0 else None)
                   for i in range(N_REQUESTS)]
        while mt.pending():
            mt.poll()
        return [h.result() for h in handles]

    mt_pass(REPS)              # warm pass (results are host numpy already)
    mt_ts = []
    for r in range(REPS):
        t0 = time.perf_counter()
        mt_pass(r)             # fetches materialize inside the timed region
        mt_ts.append(time.perf_counter() - t0)
    mt_s = min(mt_ts)
    mts = mt.stats
    assert mts["cold_dispatches"] == 0, "admission path compiled mid-timing"

    return [
        {"name": "serve_static_loop",
         "us_per_call": round(static_s * 1e6, 1),
         "derived": {"req_per_s": round(N_REQUESTS / static_s, 2),
                     "padded_lanes":
                         (-N_REQUESTS) % MAX_BATCH if N_REQUESTS % MAX_BATCH
                         else 0}},
        {"name": "serve_engine",
         "us_per_call": round(engine_s * 1e6, 1),
         "derived": {"req_per_s": round(N_REQUESTS / engine_s, 2),
                     "speedup_vs_static": round(static_s / engine_s, 3),
                     "padded_lanes": stats["padded_lanes"],
                     "buckets": list(stats["buckets"]),
                     "warmup_s": round(sum(warm.values()), 2)}},
        {"name": "serve_engine_p50",
         "us_per_call": round(p50_s * 1e6, 1),
         "derived": {"bucket": 1}},
        {"name": "serve_multitenant",
         "us_per_call": round(mt_s * 1e6, 1),
         "derived": {"req_per_s": round(N_REQUESTS / mt_s, 2),
                     "overhead_vs_engine": round(mt_s / engine_s, 3),
                     "served_by_class": mts["served_by_class"],
                     "rejected": {c: v["rejected"]
                                  for c, v in mts["priorities"].items()}}},
    ]
