"""Benchmark: data-parallel scaling of the RL train step
(``repro.distributed`` tentpole).

Spawns one subprocess per mesh size (the host-device-count XLA flag must be
set before jax initializes) with dp ∈ {1, 2, 4} faked CPU devices, trains a
few reduced-scale steps, and reports mean post-compile step time.  On faked
CPU host devices all "devices" share the same cores, so this measures
*overhead* of the sharded path (resharding + collectives + gradient
accumulation), not speedup — the derived column reports the slowdown factor
vs dp=1, which should stay near 1 (the subsystem is communication-light:
params replicated, one grad all-reduce per step).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

STEPS = 4
DP_SIZES = (1, 2, 4)

_CHILD = r"""
import json, time
import jax, jax.numpy as jnp
from repro import configs, registry
from repro.config import DistConfig, FlowRLConfig, OptimConfig, RewardSpec

dp = {dp}
flow = FlowRLConfig(num_steps=4, group_size=4, latent_tokens=8, latent_dim=8,
                    clip_range=0.2,
                    rewards=(RewardSpec("text_render", 1.0,
                             args={{"latent_dim": 8, "latent_tokens": 8}}),))
opt = OptimConfig(lr=1e-3, total_steps=50, warmup_steps=2)
key = jax.random.PRNGKey(0)
tr = registry.build("trainer", "flow_grpo", configs.get_reduced("flux_dit"),
                    flow, opt, key=key, dist=DistConfig(data_parallel=dp))
cond = jax.random.normal(key, (4, 4, 512), jnp.float32)
tr.step(cond, key, it=0)                         # compile
t0 = time.time()
for it in range(1, 1 + {steps}):
    m = tr.step(cond, key, it=it)
jax.block_until_ready(tr.state.params)
dt = (time.time() - t0) / {steps}
print(json.dumps({{"dp": dp, "devices": jax.local_device_count(),
                   "step_s": dt}}))
"""


def _child_env(dp: int) -> Dict[str, str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={dp}")
    env["JAX_PLATFORMS"] = "cpu"
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(here, "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    return env


def run() -> List[Dict]:
    rows: List[Dict] = []
    base_s = None
    for dp in DP_SIZES:
        code = _CHILD.format(dp=dp, steps=STEPS)
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True,
                              env=_child_env(dp), timeout=540)
        if proc.returncode != 0:
            raise RuntimeError(f"dp={dp} child failed:\n{proc.stderr}")
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        if base_s is None:
            base_s = out["step_s"]
        rows.append({
            "name": f"train_step_dp{dp}",
            "us_per_call": round(out["step_s"] * 1e6, 1),
            "derived": {"devices": out["devices"],
                        "overhead_vs_dp1": round(out["step_s"] / base_s, 3)},
        })
    return rows
