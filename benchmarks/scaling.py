"""Benchmark: mesh-layout scaling of the RL train step
(``repro.distributed`` tentpole).

Spawns one subprocess per mesh layout (the host-device-count XLA flag must
be set before jax initializes) over dp×mp ∈ {1×1, 2×1, 4×1, 2×2} faked CPU
devices, trains a few reduced-scale steps, and reports mean post-compile
step time plus the per-device state bytes under the active PartitionPlan.
On faked CPU host devices all "devices" share the same cores, so this
measures *overhead* of the sharded paths (resharding + collectives +
gradient accumulation), not speedup — the derived column reports the
slowdown factor vs single-device, which should stay near 1 for dp-only
layouts (params replicated, one grad all-reduce per step) and shows the
gather/reduce-scatter cost the model axis adds in exchange for the
per-device memory drop (``state_per_device_bytes``).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from typing import Dict, List

STEPS = 4
LAYOUTS = ((1, 1), (2, 1), (4, 1), (2, 2))

_CHILD = r"""
import json, time
import jax, jax.numpy as jnp
from repro import configs, registry
from repro.config import DistConfig, FlowRLConfig, OptimConfig, RewardSpec
from repro.perf.memory import state_bytes

dp, mp = {dp}, {mp}
flow = FlowRLConfig(num_steps=4, group_size=4, latent_tokens=8, latent_dim=8,
                    clip_range=0.2,
                    rewards=(RewardSpec("text_render", 1.0,
                             args={{"latent_dim": 8, "latent_tokens": 8}}),))
opt = OptimConfig(lr=1e-3, total_steps=50, warmup_steps=2)
key = jax.random.PRNGKey(0)
tr = registry.build("trainer", "flow_grpo", configs.get_reduced("flux_dit"),
                    flow, opt, key=key,
                    dist=DistConfig(data_parallel=dp, model_parallel=mp))
cond = jax.random.normal(key, (4, 4, 512), jnp.float32)
tr.step(cond, key, it=0)                         # compile
t0 = time.time()
for it in range(1, 1 + {steps}):
    m = tr.step(cond, key, it=it)
jax.block_until_ready(tr.state.params)
dt = (time.time() - t0) / {steps}
print(json.dumps({{"dp": dp, "mp": mp, "devices": jax.local_device_count(),
                   "step_s": dt, "state": state_bytes(tr)}}))
"""


def _child_env(n_devices: int) -> Dict[str, str]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                        f"{n_devices}")
    env["JAX_PLATFORMS"] = "cpu"
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(here, "src")
    env["PYTHONPATH"] = (src + os.pathsep + env["PYTHONPATH"]
                         if env.get("PYTHONPATH") else src)
    return env


def run() -> List[Dict]:
    rows: List[Dict] = []
    base_s = None
    for dp, mp in LAYOUTS:
        code = _CHILD.format(dp=dp, mp=mp, steps=STEPS)
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True,
                              env=_child_env(dp * mp), timeout=540)
        if proc.returncode != 0:
            raise RuntimeError(f"dp={dp} mp={mp} child failed:\n"
                               f"{proc.stderr}")
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        if base_s is None:
            base_s = out["step_s"]
        # dp-only rows keep their historical names so stored benchmark
        # trajectories stay comparable across runs
        name = (f"train_step_dp{dp}" if mp == 1
                else f"train_step_dp{dp}mp{mp}")
        rows.append({
            "name": name,
            "us_per_call": round(out["step_s"] * 1e6, 1),
            "derived": {"devices": out["devices"],
                        "overhead_vs_dp1": round(out["step_s"] / base_s, 3),
                        "state_per_device_bytes":
                            out["state"]["per_device_bytes"],
                        "state_sharded_leaves":
                            out["state"]["sharded_leaves"]},
        })
    return rows
