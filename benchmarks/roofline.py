"""Roofline analysis (deliverable g): per (arch × shape × mesh) derive the
three roofline terms from the dry-run artifacts:

  compute    = FLOPs / (chips × 197 TF/s bf16)
  memory     = HBM bytes / (chips × 819 GB/s)
  collective = per-device link bytes moved / (50 GB/s ICI)

FLOPs/HBM come from the analytic cost model (the CPU backend's
cost_analysis() counts scan bodies once — documented in launch/costs.py);
collective bytes come from the trip-count-corrected HLO parse (they are
already per-device post-SPMD).  Single-pod numbers only, per the assignment;
the multi-pod artifacts prove the "pod" axis lowers.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(mesh: str = "pod16x16", variant: Optional[str] = "baseline"
                 ) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh:
            continue
        if variant is not None and rec.get("variant") != variant:
            continue
        out.append(rec)
    return out


def roofline_row(rec: Dict) -> Optional[Dict]:
    ana = rec.get("analytic") or {}
    if not ana or "flops" in ana.get("error", ""):
        return None
    chips = rec["n_devices"]
    flops = ana["flops"]
    flops_kernel = ana["flops_kernel"]
    model_flops = ana["model_flops"]
    hbm = ana["hbm_bytes"]
    coll = rec["collectives"]["_total"]["moved_bytes"]  # per device already

    t_comp = flops / (chips * PEAK_FLOPS_BF16)
    t_comp_k = flops_kernel / (chips * PEAK_FLOPS_BF16)
    t_mem = hbm / (chips * HBM_BW)
    t_coll = coll / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        "arch": rec["arch"],
        "shape": rec["shape"],
        "mesh": rec["mesh"],
        "variant": rec.get("variant", "baseline"),
        "chips": chips,
        "compute_s": t_comp,
        "compute_s_kernel": t_comp_k,
        "memory_s": t_mem,
        "collective_s": t_coll,
        "dominant": dominant,
        "bound_s": bound,
        "model_flops": model_flops,
        "hlo_flops": flops,
        "useful_ratio": model_flops / flops if flops else 0.0,
        "mfu_upper_bound": (model_flops / (chips * PEAK_FLOPS_BF16)) / bound
        if bound else 0.0,
        "peak_bytes_per_dev": rec["memory"].get("peak_bytes"),
        "fits_16g": (rec["memory"].get("peak_bytes") or 0) <= 16e9,
    }


def run() -> List[Dict]:
    rows = []
    for rec in load_records():
        r = roofline_row(rec)
        if r is None:
            continue
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "us_per_call": round(r["bound_s"] * 1e6, 1),
            "derived": {
                "dominant": r["dominant"],
                "compute_ms": round(r["compute_s"] * 1e3, 3),
                "memory_ms": round(r["memory_s"] * 1e3, 3),
                "collective_ms": round(r["collective_s"] * 1e3, 3),
                "useful_ratio": round(r["useful_ratio"], 3),
                "mfu_upper_bound": round(r["mfu_upper_bound"], 3),
                "fits_16g": r["fits_16g"],
            },
        })
    return rows


def table(mesh: str = "pod16x16", variant: Optional[str] = "baseline"
          ) -> List[Dict]:
    return [r for r in (roofline_row(rec) for rec in
                        load_records(mesh, variant)) if r]
