"""Benchmark: the RL train step under the ``repro.perf`` policies.

Starts the train-step perf trajectory (ISSUE 5): step time per
trainer × remat mode × fused/unfused on the reduced arch, measured
round-robin interleaved (every config is timed in every round, so drift in
machine load biases no config), plus ``memory_analysis()`` peak temp bytes
per remat mode at ``num_steps=8`` — the memory criterion is asserted here
(compile-time analysis is deterministic; timing is only reported).

``python -m benchmarks.train_step`` (``make bench-train``) writes
``BENCH_train_step.json`` at the repo root; ``benchmarks/run.py`` runs the
same matrix for the CSV report.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

NUM_STEPS = 8          # the memory criterion's num_steps>=8 regime
PROMPTS = 4
GROUP = 4
STEPS_PER_ROUND = 3
ROUNDS = 3
TRAINERS = ("flow_grpo", "nft")
REMATS = ("none", "scan")

OUT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_train_step.json")


def _flow():
    from repro.config import FlowRLConfig, RewardSpec
    return FlowRLConfig(
        num_steps=NUM_STEPS, group_size=GROUP, latent_tokens=8, latent_dim=8,
        clip_range=0.2,
        rewards=(RewardSpec("text_render", 1.0,
                 args={"latent_dim": 8, "latent_tokens": 8}),))


def _make(trainer_type: str, perf):
    import jax
    from repro import configs, registry
    from repro.config import OptimConfig
    opt = OptimConfig(lr=1e-3, total_steps=1000, warmup_steps=2)
    return registry.build("trainer", trainer_type,
                          configs.get_reduced("flux_dit"), _flow(), opt,
                          key=jax.random.PRNGKey(0), perf=perf)


def _bench_steps() -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.config import PerfConfig
    key = jax.random.PRNGKey(0)
    cond = jax.random.normal(key, (PROMPTS, 4, 512), jnp.float32)

    grid = [(tt, remat, fuse) for tt in TRAINERS for remat in REMATS
            for fuse in (False, True)]
    entries = []
    for tt, remat, fuse in grid:
        tr = _make(tt, PerfConfig(remat=remat, fuse_step=fuse))
        tr.step(cond, key, it=0)                       # compile
        jax.block_until_ready(tr.state.params)
        entries.append({"trainer": tt, "remat": remat, "fuse": fuse,
                        "tr": tr, "best_s": float("inf"), "it": 1})

    for _ in range(ROUNDS):                            # interleaved rounds
        for e in entries:
            t0 = time.perf_counter()
            for _ in range(STEPS_PER_ROUND):
                e["tr"].step(cond, key, it=e["it"])
                e["it"] += 1
            jax.block_until_ready(e["tr"].state.params)
            e["best_s"] = min(e["best_s"],
                              (time.perf_counter() - t0) / STEPS_PER_ROUND)

    base = {tt: next(e["best_s"] for e in entries
                     if e["trainer"] == tt and e["remat"] == "none"
                     and not e["fuse"]) for tt in TRAINERS}
    return [{"trainer": e["trainer"], "remat": e["remat"], "fuse": e["fuse"],
             "step_ms": round(e["best_s"] * 1e3, 2),
             "speedup_vs_unoptimized": round(base[e["trainer"]] / e["best_s"],
                                             3)}
            for e in entries]


def _bench_memory() -> Dict:
    """Peak temp bytes of the compiled update per remat mode (AOT — nothing
    runs).  Asserts the ISSUE 5 acceptance criterion: remat="scan" cuts
    temp bytes by >= 30% at num_steps>=8."""
    import jax
    import jax.numpy as jnp
    from repro.config import PerfConfig
    cond = jax.ShapeDtypeStruct((PROMPTS, 4, 512), jnp.float32)
    out: Dict[str, Dict] = {}
    for mode in ("none", "scan", "block"):
        tr = _make("flow_grpo", PerfConfig(remat=mode))
        out[mode] = tr.memory_stats(cond)["update"]
    none_t, scan_t = out["none"]["temp_bytes"], out["scan"]["temp_bytes"]
    out["scan_temp_reduction"] = round(1.0 - scan_t / none_t, 3)
    assert scan_t <= 0.7 * none_t, (
        f"remat=scan temp bytes {scan_t} not >=30% below none {none_t}")
    return out


def collect() -> Dict:
    steps = _bench_steps()
    mem = _bench_memory()
    fused_speedup = {
        tt: round(next(s["step_ms"] for s in steps if s["trainer"] == tt
                       and s["remat"] == "none" and not s["fuse"])
                  / next(s["step_ms"] for s in steps if s["trainer"] == tt
                         and s["remat"] == "none" and s["fuse"]), 3)
        for tt in TRAINERS}
    return {
        "config": {"arch": "flux_dit/reduced", "num_steps": NUM_STEPS,
                   "prompts": PROMPTS, "group_size": GROUP,
                   "batch": PROMPTS * GROUP,
                   "steps_per_round": STEPS_PER_ROUND, "rounds": ROUNDS},
        "steps": steps,
        "memory": mem,
        "criteria": {"fused_speedup_vs_three_jit": fused_speedup,
                     "scan_temp_reduction": mem["scan_temp_reduction"]},
    }


def run() -> List[Dict]:
    """benchmarks/run.py entry point: one CSV row per timed config plus a
    memory row per remat mode."""
    res = collect()
    rows = [{
        "name": "train_step_{}_{}{}".format(s["trainer"], s["remat"],
                                            "_fused" if s["fuse"] else ""),
        "us_per_call": round(s["step_ms"] * 1e3, 1),
        "derived": {"speedup_vs_unoptimized": s["speedup_vs_unoptimized"]},
    } for s in res["steps"]]
    for mode in ("none", "scan", "block"):
        rows.append({
            "name": f"train_step_mem_{mode}",
            "us_per_call": 0.0,
            "derived": {"temp_bytes": res["memory"][mode]["temp_bytes"]},
        })
    return rows


def main() -> None:
    res = collect()
    with open(OUT_JSON, "w") as f:
        json.dump(res, f, indent=1)
    print(f"[bench] wrote {OUT_JSON}")
    for s in res["steps"]:
        print(f"  {s['trainer']:>10} remat={s['remat']:<5} "
              f"fuse={str(s['fuse']):<5} {s['step_ms']:8.2f} ms  "
              f"({s['speedup_vs_unoptimized']:.3f}x vs unoptimized)")
    print(f"  fused speedup vs three-jit path: "
          f"{res['criteria']['fused_speedup_vs_three_jit']}")
    print(f"  remat=scan temp-bytes reduction: "
          f"{res['criteria']['scan_temp_reduction']:.1%}")


if __name__ == "__main__":
    main()
