"""Benchmark: the RL train step under the ``repro.perf`` policies.

Starts the train-step perf trajectory (ISSUE 5): step time per
trainer × remat mode × fused/unfused on the reduced arch, measured
round-robin interleaved (every config is timed in every round, so drift in
machine load biases no config), plus ``memory_analysis()`` peak temp bytes
per remat mode at ``num_steps=8`` — the memory criterion is asserted here
(compile-time analysis is deterministic; timing is only reported).

The pipeline trajectory (ISSUE 10) runs the full ``TrainLoop`` at
``loop.pipeline`` K=1/2/4 plus a reward-offload config in the regime
pipelining targets (micro arch, cache-backed conditions, a durable
per-step metric log whose export latency is a pure IO wait — emulated,
see ``PIPELINE_EXPORT_WAIT_S``) and asserts the steady-state criterion:
some K>=2 depth reaches >= 1.10x the sequential K=1 drained-steps/sec.

``python -m benchmarks.train_step`` (``make bench-train``) writes
``BENCH_train_step.json`` at the repo root; ``benchmarks/run.py`` runs the
same matrix for the CSV report.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

NUM_STEPS = 8          # the memory criterion's num_steps>=8 regime
PROMPTS = 4
GROUP = 4
STEPS_PER_ROUND = 3
ROUNDS = 3
TRAINERS = ("flow_grpo", "nft")
REMATS = ("none", "scan")

PIPELINE_DEPTHS = (1, 2, 4)
PIPELINE_STEPS = 40        # steady-state window per run (first drain excluded)
PIPELINE_ROUNDS = 2        # best-of, interleaved across depths
PIPELINE_SPEEDUP_MIN = 1.10
# Emulated durable-export latency in the drain sink (pure IO wait, no CPU:
# a replicated log / remote metric endpoint / rotational fsync).  This
# container's local fsync is ~0.1ms on virtio ext4 — too fast to overlap —
# and on a single-core host pipelining can only hide *waits*, never CPU
# (total CPU time is fixed regardless of overlap).  The injected wait makes
# the leg a deterministic check of the overlap machinery itself: K=1 pays
# it serially every step, K>=2 hides it iff the loop truly keeps steps in
# flight — a regression that serializes the loop shows ~1.0x on any host.
PIPELINE_EXPORT_WAIT_S = 0.006
# The pipelined configs run with ``dist.donate_state=false``: on the CPU
# PJRT client a *donated* execution whose input buffer came off the device
# runs synchronously — ``trainer.step`` only returns once the update has
# finished, so nothing is ever in flight and K is irrelevant (the
# "k4-donate" row documents this: ~1.0x).  On GPU/TPU donation dispatches
# asynchronously and should stay on; double-buffering the micro state here
# costs nothing.

OUT_JSON = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_train_step.json")


def _flow():
    from repro.config import FlowRLConfig, RewardSpec
    return FlowRLConfig(
        num_steps=NUM_STEPS, group_size=GROUP, latent_tokens=8, latent_dim=8,
        clip_range=0.2,
        rewards=(RewardSpec("text_render", 1.0,
                 args={"latent_dim": 8, "latent_tokens": 8}),))


def _make(trainer_type: str, perf):
    import jax
    from repro import configs, registry
    from repro.config import OptimConfig
    opt = OptimConfig(lr=1e-3, total_steps=1000, warmup_steps=2)
    return registry.build("trainer", trainer_type,
                          configs.get_reduced("flux_dit"), _flow(), opt,
                          key=jax.random.PRNGKey(0), perf=perf)


def _bench_steps() -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.config import PerfConfig
    key = jax.random.PRNGKey(0)
    cond = jax.random.normal(key, (PROMPTS, 4, 512), jnp.float32)

    grid = [(tt, remat, fuse) for tt in TRAINERS for remat in REMATS
            for fuse in (False, True)]
    entries = []
    for tt, remat, fuse in grid:
        tr = _make(tt, PerfConfig(remat=remat, fuse_step=fuse))
        tr.step(cond, key, it=0)                       # compile
        jax.block_until_ready(tr.state.params)
        entries.append({"trainer": tt, "remat": remat, "fuse": fuse,
                        "tr": tr, "best_s": float("inf"), "it": 1})

    for _ in range(ROUNDS):                            # interleaved rounds
        for e in entries:
            t0 = time.perf_counter()
            for _ in range(STEPS_PER_ROUND):
                e["tr"].step(cond, key, it=e["it"])
                e["it"] += 1
            jax.block_until_ready(e["tr"].state.params)
            e["best_s"] = min(e["best_s"],
                              (time.perf_counter() - t0) / STEPS_PER_ROUND)

    base = {tt: next(e["best_s"] for e in entries
                     if e["trainer"] == tt and e["remat"] == "none"
                     and not e["fuse"]) for tt in TRAINERS}
    return [{"trainer": e["trainer"], "remat": e["remat"], "fuse": e["fuse"],
             "step_ms": round(e["best_s"] * 1e3, 2),
             "speedup_vs_unoptimized": round(base[e["trainer"]] / e["best_s"],
                                             3)}
            for e in entries]


def _bench_memory() -> Dict:
    """Peak temp bytes of the compiled update per remat mode (AOT — nothing
    runs).  Asserts the ISSUE 5 acceptance criterion: remat="scan" cuts
    temp bytes by >= 30% at num_steps>=8."""
    import jax
    import jax.numpy as jnp
    from repro.config import PerfConfig
    cond = jax.ShapeDtypeStruct((PROMPTS, 4, 512), jnp.float32)
    out: Dict[str, Dict] = {}
    for mode in ("none", "scan", "block"):
        tr = _make("flow_grpo", PerfConfig(remat=mode))
        out[mode] = tr.memory_stats(cond)["update"]
    none_t, scan_t = out["none"]["temp_bytes"], out["scan"]["temp_bytes"]
    out["scan_temp_reduction"] = round(1.0 - scan_t / none_t, 3)
    assert scan_t <= 0.7 * none_t, (
        f"remat=scan temp bytes {scan_t} not >=30% below none {none_t}")
    return out


def _bench_pipeline() -> Dict:
    """Steady-state drained-steps/sec of the full ``TrainLoop`` per
    pipeline depth, in the regime pipelining targets: the metric drain
    path carries an IO wait that is a real fraction of the step, so
    overlapping it with the in-flight device step pays.  A small arch
    keeps the device step ~30ms (large vs the ~3ms dispatch overhead, so
    there is real in-flight work); conditions come from the preprocessing
    cache; the per-step metric record is appended to a JSONL file, fsynced,
    and held for ``PIPELINE_EXPORT_WAIT_S`` of emulated export latency
    (see the constant's comment: on this container local fsync is ~0.1ms
    and the host has one core, so only an injected pure wait can expose
    overlap — which also makes the criterion deterministic across hosts).
    The pipelined rows run un-donated (see the ``donate_state`` comment
    above); ``k4-donate`` documents the CPU-client serialization.

    Uses the loop's own ``steps_per_s`` (drained steps over the window
    anchored at the second step's dispatch, excluding the compile-laden
    first step), best-of-``PIPELINE_ROUNDS`` interleaved rounds per
    config.  Asserts the ISSUE 10 criterion:
    best K>=2 >= 1.10x sequential."""
    import dataclasses
    import tempfile

    import jax
    from repro import configs, registry
    from repro.api import loop as loop_lib
    from repro.config import DistConfig, FlowRLConfig, OptimConfig, \
        PerfConfig, RewardSpec
    from repro.core.preprocess import ConditionProvider, PreprocessCache, \
        preprocess_dataset
    from repro.data.prompts import PromptDataset, synthetic_prompts

    # small-but-not-micro: the device step (~30ms) must dominate the host
    # dispatch overhead (~3ms) or nothing is ever actually in flight
    arch = dataclasses.replace(configs.get_reduced("flux_dit"), n_layers=2,
                               d_model=128, n_heads=4, n_kv_heads=4,
                               d_ff=256)
    flow = FlowRLConfig(
        num_steps=2, group_size=2, latent_tokens=4, latent_dim=4,
        rewards=(RewardSpec("text_render", 1.0,
                 args={"latent_dim": 4, "latent_tokens": 4}),))
    opt = OptimConfig(lr=1e-3, total_steps=10_000, warmup_steps=2)
    prompts = synthetic_prompts(32)
    key = jax.random.PRNGKey(0)

    class DurableEventLog(loop_lib.Callback):
        """One JSONL record per drained step: append + fsync + the
        emulated export wait (IO sleep, no CPU)."""

        def __init__(self, path: str):
            self.f = open(path, "a")

        def on_step(self, loop, step, metrics):
            self.f.write(json.dumps(metrics) + "\n")
            self.f.flush()
            os.fsync(self.f.fileno())
            time.sleep(PIPELINE_EXPORT_WAIT_S)

    with tempfile.TemporaryDirectory() as td:
        cache = PreprocessCache(os.path.join(td, "cache"))
        preprocess_dataset(prompts, cache, cond_dim=512, cond_len=4,
                           vocab=2048, hidden=256)

        nodonate = DistConfig(donate_state=False)
        grid = [(f"k{d}", d, None, nodonate) for d in PIPELINE_DEPTHS]
        grid.append(("k4-donate", 4, None, None))
        grid.append(("k2-offload", 2, PerfConfig(offload_rewards=True),
                     nodonate))

        # one trainer per config, shared across rounds (a fresh trainer
        # would recompile its jits every round)
        trainers = {
            tag: registry.build("trainer", "flow_grpo", arch, flow, opt,
                                key=jax.random.PRNGKey(0), perf=perf,
                                **({"dist": dist} if dist else {}))
            for tag, _, perf, dist in grid}

        def one_run(tag: str, rnd: int, depth: int) -> float:
            provider = ConditionProvider(preprocessing=True, cache=cache)
            ds = PromptDataset(prompts, batch_size=PROMPTS, seed=0)
            sink = DurableEventLog(os.path.join(td, f"ev-{tag}-{rnd}.jsonl"))
            lp = loop_lib.TrainLoop(trainers[tag], provider, ds,
                                    steps=PIPELINE_STEPS, key=key,
                                    pipeline=depth, callbacks=[sink])
            return lp.run()[-1]["steps_per_s"]

        best: Dict[str, float] = {tag: 0.0 for tag, _, _, _ in grid}
        for rnd in range(PIPELINE_ROUNDS):      # interleaved, like steps[]
            for tag, depth, _, _ in grid:
                best[tag] = max(best[tag], one_run(tag, rnd, depth))

    speedup = round(max(best["k2"], best["k4"]) / best["k1"], 3)
    out = {
        "config": {"arch": "flux_dit/small (2L, d128)", "num_steps": 2,
                   "prompts": PROMPTS, "group_size": 2,
                   "loop_steps": PIPELINE_STEPS,
                   "rounds": PIPELINE_ROUNDS,
                   "drain_sink": "jsonl+fsync per step",
                   "export_wait_ms": PIPELINE_EXPORT_WAIT_S * 1e3,
                   "donate_state": "false on pipelined rows (CPU client "
                                   "runs donated dispatches synchronously)"},
        "steady_steps_per_s": {tag: round(v, 3) for tag, v in best.items()},
        "pipeline_speedup": speedup,
    }
    assert speedup >= PIPELINE_SPEEDUP_MIN, (
        f"pipelined steady-state steps/s only {speedup}x sequential "
        f"(need >= {PIPELINE_SPEEDUP_MIN}x): {out['steady_steps_per_s']}")
    return out


def collect() -> Dict:
    steps = _bench_steps()
    mem = _bench_memory()
    fused_speedup = {
        tt: round(next(s["step_ms"] for s in steps if s["trainer"] == tt
                       and s["remat"] == "none" and not s["fuse"])
                  / next(s["step_ms"] for s in steps if s["trainer"] == tt
                         and s["remat"] == "none" and s["fuse"]), 3)
        for tt in TRAINERS}
    pipe = _bench_pipeline()
    return {
        "config": {"arch": "flux_dit/reduced", "num_steps": NUM_STEPS,
                   "prompts": PROMPTS, "group_size": GROUP,
                   "batch": PROMPTS * GROUP,
                   "steps_per_round": STEPS_PER_ROUND, "rounds": ROUNDS},
        "steps": steps,
        "memory": mem,
        "pipeline": pipe,
        "criteria": {"fused_speedup_vs_three_jit": fused_speedup,
                     "scan_temp_reduction": mem["scan_temp_reduction"],
                     "pipeline_speedup": pipe["pipeline_speedup"]},
    }


def run() -> List[Dict]:
    """benchmarks/run.py entry point: one CSV row per timed config plus a
    memory row per remat mode."""
    res = collect()
    rows = [{
        "name": "train_step_{}_{}{}".format(s["trainer"], s["remat"],
                                            "_fused" if s["fuse"] else ""),
        "us_per_call": round(s["step_ms"] * 1e3, 1),
        "derived": {"speedup_vs_unoptimized": s["speedup_vs_unoptimized"]},
    } for s in res["steps"]]
    for mode in ("none", "scan", "block"):
        rows.append({
            "name": f"train_step_mem_{mode}",
            "us_per_call": 0.0,
            "derived": {"temp_bytes": res["memory"][mode]["temp_bytes"]},
        })
    for tag, sps in res["pipeline"]["steady_steps_per_s"].items():
        rows.append({
            "name": f"train_loop_pipeline_{tag}",
            "us_per_call": round(1e6 / sps, 1) if sps else 0.0,
            "derived": {"steady_steps_per_s": sps},
        })
    return rows


def main() -> None:
    res = collect()
    with open(OUT_JSON, "w") as f:
        json.dump(res, f, indent=1)
    print(f"[bench] wrote {OUT_JSON}")
    for s in res["steps"]:
        print(f"  {s['trainer']:>10} remat={s['remat']:<5} "
              f"fuse={str(s['fuse']):<5} {s['step_ms']:8.2f} ms  "
              f"({s['speedup_vs_unoptimized']:.3f}x vs unoptimized)")
    print(f"  fused speedup vs three-jit path: "
          f"{res['criteria']['fused_speedup_vs_three_jit']}")
    print(f"  remat=scan temp-bytes reduction: "
          f"{res['criteria']['scan_temp_reduction']:.1%}")
    for tag, sps in res["pipeline"]["steady_steps_per_s"].items():
        print(f"  train_loop pipeline {tag:>10}: {sps:8.2f} steps/s")
    print(f"  pipeline speedup (best K>=2 vs K=1): "
          f"{res['criteria']['pipeline_speedup']:.3f}x")


if __name__ == "__main__":
    main()
