"""Benchmark: paper Table 2 — training efficiency with vs without
preprocessing-based memory optimization.

Measures, at CI scale, the two quantities of the paper's table:
  * per-step time (cached embeddings eliminate redundant encoding),
  * resident frozen-encoder bytes (the offload saving).
The paper reports 1.74× step speedup and −13% peak memory on 8×H200; the
benchmark asserts the same *direction* with the stub encoder.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from typing import Dict, List

import jax

from repro import configs, registry
from repro.config import FlowRLConfig, OptimConfig, RewardSpec
from repro.core.preprocess import (ConditionProvider, FrozenTextEncoder,
                                   PreprocessCache, preprocess_dataset)
from repro.data import PromptDataset, synthetic_prompts

STEPS = 6
# heavy frozen tower (T5-class cost profile relative to the CI-scale
# trainer): re-encoding this every step is what preprocessing eliminates
ENC_KW = dict(cond_dim=512, cond_len=16, vocab=16384, hidden=4096, depth=12)


def _run_mode(preprocessing: bool, tmp: str) -> Dict[str, float]:
    key = jax.random.PRNGKey(0)
    prompts = synthetic_prompts(16)
    if preprocessing:
        cache = PreprocessCache(tmp)
        preprocess_dataset(prompts, cache,
                           encoder=FrozenTextEncoder(**ENC_KW))
        provider = ConditionProvider(preprocessing=True, cache=cache)
    else:
        provider = ConditionProvider(preprocessing=False, encoder_kw=ENC_KW)

    flow = FlowRLConfig(
        num_steps=4, group_size=4, latent_tokens=8, latent_dim=8,
        rewards=(RewardSpec("text_render", 1.0,
                            args={"latent_dim": 8, "latent_tokens": 8}),))
    trainer = registry.build("trainer", "flow_grpo",
                             configs.get_reduced("flux_dit"), flow,
                             OptimConfig(total_steps=STEPS), key=key)
    ds = PromptDataset(prompts, batch_size=4)
    it = ds.infinite()
    # warmup (compile)
    cond = provider.get(next(it))["cond"]
    trainer.step(cond, key, it=0)
    t0 = time.perf_counter()
    for i in range(1, STEPS + 1):
        cond = provider.get(next(it))["cond"]
        trainer.step(cond, key, it=i)
    dt = (time.perf_counter() - t0) / STEPS
    return {"s_per_step": dt,
            "encoder_resident_bytes": provider.resident_param_bytes}


def run() -> List[Dict]:
    tmp = tempfile.mkdtemp(prefix="repro_preproc_bench_")
    try:
        base = _run_mode(False, tmp)
        opt = _run_mode(True, tmp)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    speedup = base["s_per_step"] / max(opt["s_per_step"], 1e-9)
    saved = base["encoder_resident_bytes"] - opt["encoder_resident_bytes"]
    return [{
        "name": "preprocessing/table2",
        "us_per_call": round(opt["s_per_step"] * 1e6, 1),
        "derived": {
            "s_per_step_without": round(base["s_per_step"], 4),
            "s_per_step_with": round(opt["s_per_step"], 4),
            "speedup": round(speedup, 3),
            "encoder_bytes_without": base["encoder_resident_bytes"],
            "encoder_bytes_with": opt["encoder_resident_bytes"],
            "offloaded_bytes": saved,
            "direction_matches_paper": bool(speedup > 1.0 and saved > 0),
        },
    }]
